//! Integration: the chaos engine end to end — fault injection through
//! the in-process cluster and the TCP path, the degradation ladder, and
//! the recovery guarantees the coordinator makes:
//!
//! - at most `s` silent workers per iteration → every iteration decodes
//!   exactly (rung `Exact`) and the trained parameters match a fault-free
//!   run of the same configuration;
//! - more than `s` silent workers → the trainer degrades to the
//!   least-squares partial decode (rung `Degraded`, residual recorded)
//!   instead of erroring, and to a stale-gradient step when nothing is
//!   decodable at all;
//! - arbitrary random fault plans never panic and never hang (bounded by
//!   a wall-clock watchdog);
//! - the whole machine is deterministic in the chaos seed;
//! - the TCP master survives mid-gather disconnects (the pre-v3 hang)
//!   and checksum-rejects corrupted frames in bounded time.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use gradcode::chaos::{ChaosConfig, ChaosSpec, DegradeLadder, FaultKind, FaultPlan, GatherPolicy, LadderRung};
use gradcode::coordinator::wire::{Message, Setup, MAGIC, SCHEME_POLY};
use gradcode::coordinator::{
    remote, train, ExecutionMode, OptChoice, RemoteMaster, SchemeSpec, TrainConfig,
};
use gradcode::data::{CategoricalConfig, DenseDataset, SyntheticCategorical};
use gradcode::simulator::{degraded_fraction, DelayParams};
use gradcode::testkit::{self, check, CaseResult, Config};

fn dataset(rows: usize, seed: u64) -> DenseDataset {
    let gen = SyntheticCategorical::new(CategoricalConfig::default(), seed);
    gen.generate(rows, seed + 1)
}

/// Virtual-mode config (deterministic arrival order from the sampled
/// §VI delays) used by all in-process chaos tests.
fn base_cfg(n: usize, scheme: SchemeSpec, iters: usize) -> TrainConfig {
    TrainConfig {
        n,
        scheme,
        iters,
        opt: OptChoice::Nag { lr: 0.05, momentum: 0.9 },
        eval_every: iters,
        delays: Some(DelayParams::table_vi1()),
        mode: ExecutionMode::Virtual,
        seed: 0x0dd5,
        minibatch: None,
        quorum: None,
        fleet: None,
        chaos: None,
    }
}

/// Acceptance: with at most `s` silent workers per iteration every
/// iteration stays on the `Exact` rung and training lands on the same
/// parameters as the identical fault-free run — the decode is exact from
/// *any* `n - s` responders, so which workers were killed cannot matter.
#[test]
fn at_most_s_failures_decode_exactly_and_match_fault_free_run() {
    let ds = dataset(240, 11);
    let (n, s) = (6, 2);
    let iters = 12;
    let scheme = SchemeSpec::Poly { s, m: 1 };

    let mut plan = FaultPlan::new(n);
    // Silent faults, never more than s = 2 per iteration: worker 1 is
    // gone for good from iter 2; worker 4 drops one result at iter 5.
    plan.schedule(1, 2, FaultKind::Crash { restart_after: None });
    plan.schedule(4, 5, FaultKind::Drop);
    // Non-silent faults the robustness layer must absorb without leaving
    // the Exact rung: a duplicate delivery, a late arrival, and a
    // corrupted payload (caught by CRC, sender becomes a straggler —
    // iter 8 then has exactly n - s = 4 healthy responders).
    plan.schedule(3, 6, FaultKind::Duplicate);
    plan.schedule(2, 7, FaultKind::Delay(1.5));
    plan.schedule(5, 8, FaultKind::Corrupt);

    let mut chaos_cfg = base_cfg(n, scheme.clone(), iters);
    chaos_cfg.chaos = Some(ChaosConfig::new(plan));
    let (chaos_log, chaos_beta) = train(chaos_cfg, &ds, None).unwrap();

    let (_, clean_beta) = train(base_cfg(n, scheme, iters), &ds, None).unwrap();

    assert_eq!(
        chaos_log.rung_counts(),
        (iters, 0, 0),
        "≤ s silent workers must never leave the Exact rung: {}",
        chaos_log.faults.summary()
    );
    assert!(chaos_log.faults.injected() >= 5, "all scheduled faults logged");
    assert!(
        chaos_log.faults.checksum_rejects() >= 1,
        "the corrupt frame must be caught by checksum"
    );
    assert_eq!(chaos_beta.len(), clean_beta.len());
    let scale = clean_beta.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
    for j in 0..clean_beta.len() {
        assert!(
            (chaos_beta[j] - clean_beta[j]).abs() / scale < 1e-3,
            "coord {j}: chaos {} vs fault-free {}",
            chaos_beta[j],
            clean_beta[j]
        );
    }
}

/// Acceptance: more than `s` concurrent failures used to be fatal; with
/// a chaos config the trainer drops to the least-squares partial decode,
/// records the rung and its residual, and finishes the run.
#[test]
fn more_than_s_failures_engage_the_degrade_ladder() {
    let ds = dataset(240, 13);
    let (n, s) = (6, 1);
    let iters = 10;

    let mut plan = FaultPlan::new(n);
    // Two permanent crashes from iter 3 on: 2 > s = 1, so from then on
    // only 4 of the required n - s = 5 responders exist.
    plan.schedule(0, 3, FaultKind::Crash { restart_after: None });
    plan.schedule(1, 3, FaultKind::Crash { restart_after: None });

    let mut cfg = base_cfg(n, SchemeSpec::Poly { s, m: 1 }, iters);
    cfg.chaos = Some(ChaosConfig::new(plan));
    let (log, _beta) = train(cfg, &ds, None).unwrap();

    let (exact, degraded, stale) = log.rung_counts();
    assert_eq!(exact, 3, "iters 0..3 are fault-free");
    assert_eq!(degraded, iters - 3, "every later iteration partially decodes");
    assert_eq!(stale, 0);
    for r in &log.records {
        if r.rung == LadderRung::Degraded {
            assert_eq!(r.responders.len(), 4, "iter {}", r.iter);
            assert!(
                r.decode_residual.is_some(),
                "degraded iterations must report the LS residual (iter {})",
                r.iter
            );
        }
    }
    assert!(log.final_loss().unwrap().is_finite());
}

/// The last rung: when nothing is decodable the trainer repeats the
/// previous gradient, and aborts only after `max_stale` consecutive
/// stale iterations.
#[test]
fn total_blackout_goes_stale_then_aborts_at_the_ladder_limit() {
    let ds = dataset(160, 17);
    let n = 4;

    let mut blackout = FaultPlan::new(n);
    for w in 0..n {
        blackout.schedule(w, 2, FaultKind::Crash { restart_after: None });
    }

    // Short blackout within the allowance: the run completes on stale
    // gradients.
    let mut cfg = base_cfg(n, SchemeSpec::Poly { s: 1, m: 1 }, 5);
    cfg.chaos = Some(ChaosConfig {
        ladder: DegradeLadder { max_stale: 5 },
        ..ChaosConfig::new(blackout.clone())
    });
    let (log, _) = train(cfg, &ds, None).unwrap();
    let (exact, _degraded, stale) = log.rung_counts();
    assert_eq!(exact, 2);
    assert_eq!(stale, 3, "iters 2..5 have zero responders");

    // Longer blackout than the allowance: a clean error, not a hang.
    let mut cfg = base_cfg(n, SchemeSpec::Poly { s: 1, m: 1 }, 12);
    cfg.chaos = Some(ChaosConfig {
        ladder: DegradeLadder { max_stale: 3 },
        ..ChaosConfig::new(blackout)
    });
    let err = train(cfg, &ds, None).unwrap_err();
    assert!(
        err.to_string().contains("consecutive stale"),
        "unexpected error: {err}"
    );
}

/// Property: training under an *arbitrary* generated fault plan either
/// completes or fails with a clean error — it never panics and never
/// exceeds the watchdog. Covers every fault kind, including restartable
/// crashes and resets, over random small schemes.
#[test]
fn arbitrary_fault_plans_never_panic_or_hang() {
    let cfg = Config { cases: 12, ..Config::default() };
    check(
        cfg,
        "arbitrary_fault_plans_never_panic_or_hang",
        |rng| {
            let (n, s, m) = loop {
                let (n, _d, s, m) = testkit::gen::scheme_triple(rng, 3, 6);
                // keep at least one worker's worth of slack so the
                // fault-free iterations are plausible training steps
                if s + m < n {
                    break (n, s, m);
                }
            };
            let plan = testkit::gen::fault_plan(rng, n, 8, 6);
            (n, s, m, plan)
        },
        |&(n, s, m, ref plan)| {
            let plan = plan.clone();
            let outcome = testkit::with_watchdog(
                Duration::from_secs(120),
                "chaos-train",
                move || {
                    let ds = dataset(120, 7);
                    let mut cfg = base_cfg(n, SchemeSpec::Poly { s, m }, 8);
                    cfg.chaos = Some(ChaosConfig::new(plan));
                    train(cfg, &ds, None).map(|_| ())
                },
            );
            match outcome {
                Ok(()) => CaseResult::Pass,
                // A clean abort (e.g. the stale ladder limit) is a valid
                // recovery outcome; only panics/hangs fail the property.
                Err(_) => CaseResult::Pass,
            }
        },
    );
}

/// Determinism is the chaos engine's core contract: the same plan and
/// seed must replay bit-identically — parameters and the fault log.
#[test]
fn chaos_runs_are_bit_identical_across_replays() {
    let ds = dataset(200, 19);
    let spec = ChaosSpec::parse("crash=0.05,drop=0.1,corrupt=0.05,dup=0.05,seed=0xc0de")
        .unwrap();
    let run = || {
        let mut cfg = base_cfg(6, SchemeSpec::Poly { s: 2, m: 1 }, 15);
        cfg.chaos = Some(ChaosConfig::from_spec(6, 15, &spec));
        train(cfg, &ds, None).unwrap()
    };
    let (log_a, beta_a) = run();
    let (log_b, beta_b) = run();
    assert_eq!(beta_a, beta_b, "same seed must give bit-identical parameters");
    assert_eq!(log_a.faults.to_csv(), log_b.faults.to_csv());
    assert_eq!(log_a.rung_counts(), log_b.rung_counts());
}

/// The simulator's binomial prediction matches the engine: under i.i.d.
/// per-iteration drops at rate p, the observed degraded fraction tracks
/// `P[Bin(n, p) > s]`.
#[test]
fn observed_degraded_fraction_tracks_the_binomial_prediction() {
    let ds = dataset(160, 23);
    let (n, s, p) = (6, 2, 0.25);
    let iters = 200;
    let spec = ChaosSpec::parse("drop=0.25,seed=99").unwrap();
    let mut cfg = base_cfg(n, SchemeSpec::Poly { s, m: 1 }, iters);
    cfg.eval_every = iters; // keep the long run cheap
    cfg.chaos = Some(ChaosConfig::from_spec(n, iters as u64, &spec));
    let (log, _) = train(cfg, &ds, None).unwrap();
    let (_exact, degraded, stale) = log.rung_counts();
    let observed = (degraded + stale) as f64 / iters as f64;
    let predicted = degraded_fraction(n, s, p);
    assert!(
        (observed - predicted).abs() < 0.09,
        "observed {observed:.3} vs binomial prediction {predicted:.3} \
         over {iters} iterations"
    );
}

fn free_addr() -> std::net::SocketAddr {
    let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap();
    drop(l);
    addr
}

fn tcp_setup(n: u32, s: u32, m: u32) -> Setup {
    Setup::homogeneous(n, s + m, s, m, SCHEME_POLY, 1, 777, n * 16, 512)
}

/// Acceptance (regression): the pre-v3 `RemoteMaster` blocked forever on
/// `recv()` when a worker disconnected mid-gather. The gather must now
/// return a partial result within the policy deadline — enforced here by
/// a watchdog an order of magnitude above the deadline.
#[test]
fn tcp_master_survives_mid_gather_disconnect_in_bounded_time() {
    testkit::with_watchdog(Duration::from_secs(30), "tcp-ghost-gather", || {
        let setup = tcp_setup(2, 0, 1); // quorum = n = 2: the ghost is needed
        let addr = free_addr();
        let master = {
            let setup = setup.clone();
            std::thread::spawn(move || -> anyhow::Result<(bool, usize, f64)> {
                let mut master = RemoteMaster::listen(addr, setup.clone())?;
                master.set_gather_policy(GatherPolicy {
                    deadline: Duration::from_millis(500),
                    retries: 1,
                    backoff: Duration::from_millis(1),
                });
                let beta = vec![0.0f32; setup.dim as usize];
                let t0 = Instant::now();
                let g = master.run_iteration(0, &beta)?;
                let elapsed = t0.elapsed().as_secs_f64();
                master.shutdown();
                Ok((g.complete, g.results.len(), elapsed))
            })
        };
        let real = std::thread::spawn(move || remote::run_worker(addr, 0));
        let ghost = std::thread::spawn(move || {
            use std::io::BufWriter;
            let stream = std::net::TcpStream::connect(addr).unwrap();
            let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
            let mut writer = BufWriter::new(stream);
            Message::Hello { magic: MAGIC, worker_id: 1 }.write_to(&mut writer).unwrap();
            assert!(matches!(
                Message::read_from(&mut reader).unwrap(),
                Message::Setup(_)
            ));
            // vanish mid-gather — the pre-v3 master hung right here
        });
        let (complete, got, elapsed) = master.join().unwrap().unwrap();
        ghost.join().unwrap();
        real.join().unwrap().unwrap();
        assert!(!complete, "quorum 2 is unreachable with a ghost worker");
        assert_eq!(got, 1, "the healthy worker's result is kept");
        assert!(elapsed < 10.0, "gather took {elapsed}s, deadline is 0.5s");
    });
}

/// A deterministic corrupter on the TCP path: every frame it sends fails
/// the CRC32 check, the master rejects it (bounded re-prods, no
/// ping-pong) and completes the gather from the clean workers.
#[test]
fn tcp_corrupt_frames_are_rejected_and_training_continues() {
    testkit::with_watchdog(Duration::from_secs(60), "tcp-corrupt-gather", || {
        let (n, s, m) = (4u32, 1u32, 1u32);
        let setup = tcp_setup(n, s, m);
        let addr = free_addr();
        let iters = 3u64;
        let master = {
            let setup = setup.clone();
            std::thread::spawn(move || -> anyhow::Result<(usize, usize)> {
                let mut master = RemoteMaster::listen(addr, setup.clone())?;
                master.set_gather_policy(GatherPolicy {
                    deadline: Duration::from_secs(2),
                    retries: 1,
                    backoff: Duration::from_millis(1),
                });
                let code = remote::scheme_from_setup(&setup)?;
                let mut cache = HashMap::new();
                let beta = vec![0.0f32; setup.dim as usize];
                let mut rejects = 0usize;
                let mut decoded = 0usize;
                for iter in 0..iters {
                    let gather = master.run_iteration(iter, &beta)?;
                    rejects += gather.rejected.len();
                    assert!(
                        gather.complete,
                        "iter {iter}: 3 clean workers satisfy quorum {}",
                        setup.wait_for()
                    );
                    let grad = remote::decode_gather(code.as_ref(), &gather, &mut cache)?;
                    assert!(grad.iter().all(|g| g.is_finite()));
                    decoded += 1;
                }
                master.shutdown();
                Ok((rejects, decoded))
            })
        };
        // Worker 3 corrupts every result frame it ever sends.
        let mut corrupter = FaultPlan::new(n as usize);
        for iter in 0..iters + 8 {
            corrupter.schedule(3, iter, FaultKind::Corrupt);
        }
        let workers: Vec<_> = (0..n as usize)
            .map(|w| {
                let plan = (w == 3).then(|| corrupter.clone());
                std::thread::spawn(move || remote::run_worker_chaos(addr, w, plan))
            })
            .collect();
        let (rejects, decoded) = master.join().unwrap().unwrap();
        for h in workers {
            h.join().unwrap().unwrap();
        }
        assert_eq!(decoded, iters as usize, "every iteration decoded");
        // The corrupter answers every task, so at least one of its frames
        // is drained and checksum-rejected during the run (frames landing
        // after a quorum closes are processed by the next gather, so the
        // exact count is timing-dependent).
        assert!(rejects >= 1, "corrupted frames must be checksum-rejected");
    });
}
