//! Integration: the telemetry subsystem end to end — a traced training
//! run through the full trainer/cluster stack, and the acceptance
//! properties of the issue:
//!
//! - the master phase breakdown (broadcast, gather_wait, decode, step,
//!   eval) accounts for the iteration total to within 10%;
//! - the Chrome trace export is a valid JSON array with matched B/E
//!   pairs and one named track per worker;
//! - on a bimodal fleet the straggler report ranks the slow-group
//!   workers as the top stragglers;
//! - span guards record during panic unwind (RAII contract);
//! - the JSONL round trip preserves every aggregate the report is
//!   built from;
//! - `IterationRecord::wire_bytes` matches the framed wire layout.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use gradcode::coordinator::wire::{
    framed_result_bytes, FRAME_OVERHEAD, RESULT_HEADER_BYTES, RESULT_METRICS_BYTES,
};
use gradcode::coordinator::{
    ExecutionMode, OptChoice, SchemeSpec, SpeedProfile, TrainConfig, Trainer,
};
use gradcode::data::{CategoricalConfig, DenseDataset, SyntheticCategorical};
use gradcode::metrics::RunLog;
use gradcode::obs::{phase, Recorder};
use gradcode::simulator::DelayParams;
use gradcode::testkit::with_watchdog;

fn dataset(rows: usize, seed: u64) -> DenseDataset {
    let gen = SyntheticCategorical::new(CategoricalConfig::default(), seed);
    gen.generate(rows, seed + 1)
}

fn traced_run(cfg: TrainConfig, rows: usize, seed: u64) -> (RunLog, Recorder) {
    let ds = dataset(rows, seed);
    let mut tr = Trainer::new(cfg, &ds, None).expect("trainer builds");
    let rec = Recorder::enabled();
    tr.attach_recorder(&rec);
    let log = tr.run().expect("traced run completes");
    (log, rec)
}

/// Acceptance (a): the phase table's master phases are mutually
/// exclusive and pave each iteration — their total must land within 10%
/// of the iteration-span total. Enough rows that real compute (inside
/// gather_wait) dominates the untraced slack between spans.
#[test]
fn master_phase_sum_accounts_for_the_iteration_total() {
    let mut cfg = TrainConfig::quick(5, SchemeSpec::Poly { s: 1, m: 2 }, 30);
    cfg.eval_every = 5;
    let (log, _rec) = traced_run(cfg, 2000, 0x0b51);
    let tel = log.telemetry.expect("traced run carries a digest");
    let total = tel.iteration_total();
    let sum = tel.master_phase_sum();
    assert!(total > 0.0);
    assert!(
        (sum / total - 1.0).abs() < 0.10,
        "master phases sum to {sum:.4}s but iterations total {total:.4}s \
         ({:+.1}% off)",
        (sum / total - 1.0) * 100.0
    );
    // Every master phase actually appears in the breakdown.
    for ph in phase::MASTER_PHASES {
        assert!(
            tel.phase_total(ph).unwrap_or(0.0) > 0.0,
            "phase {ph} missing from the table"
        );
    }
}

/// Acceptance (b): the Chrome export of a real traced run is a JSON
/// array with matched B/E pairs and one named track per worker.
#[test]
fn chrome_trace_of_a_real_run_has_one_track_per_worker() {
    let cfg = TrainConfig::quick(5, SchemeSpec::Poly { s: 1, m: 2 }, 8);
    let (_log, rec) = traced_run(cfg, 400, 0x0b52);
    let json = rec.to_chrome();
    let trimmed = json.trim();
    assert!(trimmed.starts_with('[') && trimmed.ends_with(']'));
    let b = json.matches("\"ph\": \"B\"").count();
    let e = json.matches("\"ph\": \"E\"").count();
    assert!(b > 0, "a real run emits duration events");
    assert_eq!(b, e, "every B needs a matching E");
    assert!(json.contains("\"master\""));
    for w in 0..5 {
        assert!(
            json.contains(&format!("\"worker {w}\"")),
            "missing track for worker {w}"
        );
    }
    // Virtual-clock worker spans live on their own process track.
    assert!(json.contains("\"workers (virtual clock)\""));
}

/// Acceptance (c): on a bimodal fleet (slow group at speed 1, fast
/// group 4x) with compute-dominant delays, the straggler report must
/// attribute the tail to the slow group.
#[test]
fn bimodal_fleet_ranks_slow_workers_as_top_stragglers() {
    let n = 10;
    let slow: Vec<usize> = (0..4).collect(); // round(0.4 · 10) workers at speed 1
    let mut cfg = TrainConfig::quick(n, SchemeSpec::Poly { s: 2, m: 2 }, 40);
    cfg.fleet = Some(SpeedProfile::Bimodal { slow_frac: 0.4, ratio: 4.0 });
    // Compute-dominant: the t1/λ1 term dwarfs communication, so arrival
    // order tracks worker speed almost surely.
    cfg.delays =
        Some(DelayParams { lambda1: 0.8, t1: 1.6, lambda2: 10.0, t2: 0.1 });
    let (log, _rec) = traced_run(cfg, 600, 0x0b53);
    let report = log.telemetry.expect("digest").stragglers;
    assert_eq!(report.workers.len(), n);
    // s = 2 straggled responses per iteration land on the slow group.
    for w in report.top_stragglers(2) {
        assert!(
            slow.contains(&w),
            "top straggler {w} is not in the slow group {slow:?}\n{}",
            report.render()
        );
    }
    let slow_straggles: u64 = report
        .workers
        .iter()
        .filter(|w| slow.contains(&w.worker))
        .map(|w| w.straggled + w.missed)
        .sum();
    let fast_straggles: u64 = report
        .workers
        .iter()
        .filter(|w| !slow.contains(&w.worker))
        .map(|w| w.straggled + w.missed)
        .sum();
    assert!(
        slow_straggles > fast_straggles,
        "slow group straggled {slow_straggles}x vs fast {fast_straggles}x"
    );
    // The §VI model line is attached and finite.
    assert!(report.model_expected.unwrap() > 0.0);
    assert!(report.deviation.unwrap().is_finite());
}

/// The span guard's RAII contract: a panic mid-span still records the
/// span (drop runs during unwind, the poisoned lock is tolerated).
#[test]
fn span_guard_records_during_panic_unwind() {
    with_watchdog(Duration::from_secs(30), "span_raii_panic", || {
        let rec = Recorder::enabled();
        let rec2 = rec.clone();
        let result = catch_unwind(AssertUnwindSafe(move || {
            let _outer = rec2.span("outer");
            let _inner = rec2.span("doomed");
            panic!("mid-span panic");
        }));
        assert!(result.is_err(), "the closure must actually panic");
        let summary = rec.summary();
        for ph in ["outer", "doomed"] {
            let st = summary
                .phases
                .iter()
                .find(|p| p.phase == ph)
                .unwrap_or_else(|| panic!("span {ph} lost in the unwind"));
            assert_eq!(st.count, 1);
        }
    });
}

/// The JSONL round trip rebuilds every aggregate the report is built
/// from: phase histograms, straggler counts, and counters.
#[test]
fn jsonl_round_trip_preserves_the_report() {
    let cfg = TrainConfig::quick(5, SchemeSpec::Poly { s: 1, m: 2 }, 10);
    let (_log, rec) = traced_run(cfg, 400, 0x0b54);
    let text = rec.to_jsonl();
    let back = Recorder::from_jsonl(&text).expect("replay parses");
    let (a, b) = (rec.summary(), back.summary());
    assert_eq!(a.phases.len(), b.phases.len());
    for (x, y) in a.phases.iter().zip(&b.phases) {
        assert_eq!(x.phase, y.phase);
        assert_eq!(x.count, y.count, "phase {} count drifted", x.phase);
        assert!((x.total - y.total).abs() < 1e-9 * (1.0 + x.total.abs()));
    }
    assert_eq!(a.counters, b.counters);
    assert_eq!(a.stragglers.workers.len(), b.stragglers.workers.len());
    for (x, y) in a.stragglers.workers.iter().zip(&b.stragglers.workers) {
        assert_eq!((x.worker, x.used, x.straggled, x.missed), (y.worker, y.used, y.straggled, y.missed));
    }
}

/// `wire_bytes` is the framed size of every gathered Result frame:
/// length prefix + tag + Result header + payload + CRC trailer. The
/// record does not carry the frame count directly, but the layout
/// determines it: `wire_bytes = k·(overhead) + 4·floats`, so `k` is
/// recoverable and the full identity must close.
#[test]
fn wire_byte_accounting_matches_the_frame_layout() {
    let per_frame_overhead = FRAME_OVERHEAD + RESULT_HEADER_BYTES + RESULT_METRICS_BYTES;
    let cfg = TrainConfig::quick(6, SchemeSpec::Poly { s: 2, m: 2 }, 6);
    let (log, _rec) = traced_run(cfg, 480, 0x0b55);
    assert!(log.total_wire_bytes() > 0);
    for r in &log.records {
        // framing always costs more than the raw payload
        assert!(r.wire_bytes > 4 * r.floats_transmitted, "iter {}", r.iter);
        let overhead = r.wire_bytes - 4 * r.floats_transmitted;
        assert_eq!(overhead % per_frame_overhead, 0, "iter {}", r.iter);
        let frames = overhead / per_frame_overhead;
        // All gathered results are charged — at least the deciding
        // quorum prefix the record names as responders.
        assert!(frames >= r.responders.len(), "iter {}", r.iter);
        assert_eq!(r.floats_transmitted % frames, 0, "iter {}", r.iter);
        let out_dim = r.floats_transmitted / frames;
        assert_eq!(
            r.wire_bytes,
            frames * framed_result_bytes(out_dim),
            "iter {}: {frames} frames × framed({out_dim})",
            r.iter
        );
    }
}

/// Regression: `StragglerReport::ranked()` used to order tied workers
/// by whatever order the input vector happened to have — workers tied
/// on straggle count AND p90 (the norm in a symmetric fleet) came back
/// in input order, so two runs of the same fleet could print differently
/// ranked reports. The worker-id tiebreak makes the order total.
#[test]
fn straggler_ranking_is_deterministic_under_ties() {
    use gradcode::obs::{StragglerReport, WorkerObs, WorkerStat};
    let tied = |worker: usize| {
        let mut obs = WorkerObs::default();
        // identical latency stream and outcome counts for every worker:
        // straggle_count and p90 both tie exactly
        for _ in 0..4 {
            obs.latency.record(0.25);
            obs.used += 1;
        }
        obs.straggled = 1;
        obs.missed = 1;
        WorkerStat::from_obs(worker, &obs)
    };
    // Feed the rows in an order that is NOT worker order; only the id
    // tiebreak can restore determinism.
    let mut report = StragglerReport::default();
    for w in [3usize, 0, 4, 1, 5, 2] {
        report.workers.push(tied(w));
    }
    let order: Vec<usize> = report.ranked().iter().map(|s| s.worker).collect();
    assert_eq!(order, vec![0, 1, 2, 3, 4, 5], "tied workers must rank by id");
    assert_eq!(report.top_stragglers(3), vec![0, 1, 2]);
    // A genuinely worse worker still outranks the id order.
    let mut worst = tied(5);
    worst.missed += 7;
    report.workers.push(worst);
    let order: Vec<usize> =
        report.ranked().iter().map(|s| s.worker).collect();
    assert_eq!(order[0], 5, "higher straggle count beats the id tiebreak");
}

/// A disabled recorder must leave no trace: no digest on the log, no
/// events, and the run still trains.
#[test]
fn disabled_recorder_is_invisible() {
    let ds = dataset(300, 0x0b56);
    let mut cfg = TrainConfig::quick(4, SchemeSpec::Poly { s: 1, m: 1 }, 5);
    cfg.mode = ExecutionMode::Virtual;
    cfg.opt = OptChoice::Sgd { lr: 0.01 };
    let mut tr = Trainer::new(cfg, &ds, None).unwrap();
    let rec = Recorder::disabled();
    tr.attach_recorder(&rec);
    let log = tr.run().unwrap();
    assert!(log.telemetry.is_none(), "disabled recorder must not digest");
    assert!(rec.events().is_empty());
    assert!(rec.summary().phases.is_empty());
    assert_eq!(log.records.len(), 5);
}
