//! Integration: the AOT PJRT path vs the pure-rust reference backend.
//!
//! Requires `make artifacts` (shapes n=10, d=3, m∈{1,2}, rows=64, l=512
//! plus predict r=256). Tests skip with a notice when artifacts are
//! absent so `cargo test` stays green pre-`make artifacts`.

use std::path::PathBuf;
use std::sync::Arc;

use gradcode::coding::{GradientCode, PolynomialCode, SchemeConfig};
use gradcode::coordinator::{
    ComputeBackend, ExecutionMode, OptChoice, RustBackend, SchemeSpec, TrainConfig,
    Trainer,
};
use gradcode::data::{CategoricalConfig, DenseDataset, SyntheticCategorical};
use gradcode::model::LogisticModel;
use gradcode::runtime::{Manifest, PjrtBackend, PjrtEngine, PjrtPredictor};
use gradcode::simulator::DelayParams;

fn artifact_dir() -> Option<PathBuf> {
    let dir = Manifest::default_dir();
    Manifest::load(&dir).ok().filter(|m| !m.is_empty()).map(|_| dir)
}

/// Synthetic data padded to the artifact shapes (n=10, rows/subset=64,
/// l=512).
fn dataset(m: usize) -> DenseDataset {
    let cfg = CategoricalConfig {
        columns: 10,
        cardinality: (16, 48),
        ..Default::default()
    };
    let gen = SyntheticCategorical::new(cfg, 101);
    let ds = gen.generate(640, 102);
    assert!(ds.cols <= 512, "schema too wide: {}", ds.cols);
    let _ = m;
    ds.pad_cols(512)
}

#[test]
fn pjrt_worker_matches_rust_backend() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    };
    let code = PolynomialCode::new(SchemeConfig::tight(10, 1, 2).unwrap()).unwrap();
    let ds = dataset(2);
    let pjrt = PjrtBackend::new(&dir, &code, &ds).unwrap();
    let rust = RustBackend::new(&code, &ds).unwrap();
    assert_eq!(pjrt.dim(), rust.dim());
    assert_eq!(pjrt.out_dim(), rust.out_dim());

    let beta: Vec<f32> = (0..512).map(|i| ((i as f32) * 0.013).sin() * 0.05).collect();
    let mut fa = Vec::new();
    let mut fb = Vec::new();
    for w in [0usize, 3, 9] {
        pjrt.encoded_gradient(w, 0, &beta, &mut fa).unwrap();
        rust.encoded_gradient(w, 0, &beta, &mut fb).unwrap();
        assert_eq!(fa.len(), 256);
        let scale = fb.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
        for j in 0..fa.len() {
            assert!(
                (fa[j] - fb[j]).abs() / scale < 1e-3,
                "worker {w} coord {j}: pjrt {} vs rust {}",
                fa[j],
                fb[j]
            );
        }
    }
}

#[test]
fn pjrt_backend_trains_end_to_end() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    };
    let ds = dataset(2);
    let code: Arc<dyn GradientCode> =
        Arc::new(PolynomialCode::new(SchemeConfig::tight(10, 1, 2).unwrap()).unwrap());
    let backend: Arc<dyn ComputeBackend> =
        Arc::new(PjrtBackend::new(&dir, code.as_ref(), &ds).unwrap());
    let cfg = TrainConfig {
        n: 10,
        scheme: SchemeSpec::Poly { s: 1, m: 2 },
        iters: 20,
        opt: OptChoice::Nag { lr: 6.0 / ds.rows as f32, momentum: 0.9 },
        eval_every: 5,
        delays: Some(DelayParams::table_vi1()),
        mode: ExecutionMode::Virtual,
        seed: 3,
        minibatch: None,
        quorum: None,
        fleet: None,
        chaos: None,
    };
    let mut trainer = Trainer::with_backend(cfg, code, backend, &ds, None).unwrap();
    let log = trainer.run().unwrap();
    let first = log.records[0].loss.unwrap();
    let last = log.final_loss().unwrap();
    assert!(last < first, "loss must decrease: {first} -> {last}");
}

#[test]
fn pjrt_predict_matches_rust_model() {
    let Some(dir) = artifact_dir() else {
        eprintln!("SKIP: no artifacts (run `make artifacts`)");
        return;
    };
    let ds = dataset(2).select_rows(&(0..256).collect::<Vec<_>>());
    let engine = PjrtEngine::cpu().unwrap();
    let pred = PjrtPredictor::new(&engine, &dir, 256, 512).unwrap();
    let beta: Vec<f32> = (0..512).map(|i| ((i as f32) * 0.07).cos() * 0.1).collect();
    let got = pred.predict(&ds.x, &beta).unwrap();
    let want = LogisticModel::predict(&ds, &beta);
    for j in 0..256 {
        assert!((got[j] - want[j]).abs() < 1e-4, "row {j}: {} vs {}", got[j], want[j]);
    }
}
