//! Cross-module integration over the rust backend: training dynamics,
//! straggler tolerance under the virtual cluster, scheme equivalence,
//! failure injection, mini-batch SGD, and the communication accounting
//! the paper's tradeoff is about.

use std::sync::Arc;

use gradcode::coordinator::{
    train, ComputeBackend, ExecutionMode, OptChoice, RustBackend, SchemeSpec,
    TrainConfig, Trainer,
};
use gradcode::data::{train_test_split, CategoricalConfig, SyntheticCategorical};
use gradcode::simulator::DelayParams;

fn dataset(rows: usize, seed: u64) -> (gradcode::data::DenseDataset, gradcode::data::DenseDataset) {
    let gen = SyntheticCategorical::new(CategoricalConfig::default(), seed);
    let ds = gen.generate(rows, seed + 1);
    train_test_split(&ds, 0.25, seed + 2)
}

fn config(n: usize, scheme: SchemeSpec, iters: usize, lr: f32) -> TrainConfig {
    TrainConfig {
        n,
        scheme,
        iters,
        opt: OptChoice::Nag { lr, momentum: 0.9 },
        eval_every: 10,
        delays: Some(DelayParams::table_vi1()),
        mode: ExecutionMode::Virtual,
        seed: 0xabcd,
        minibatch: None,
        quorum: None,
        fleet: None,
        chaos: None,
    }
}

#[test]
fn all_three_schemes_reach_similar_auc() {
    // The paper's Fig. 4 message: same generalization, different clock.
    let (train_ds, test_ds) = dataset(1600, 201);
    let lr = 6.0 / train_ds.rows as f32;
    let mut aucs = Vec::new();
    for scheme in [
        SchemeSpec::Uncoded,
        SchemeSpec::Poly { s: 2, m: 1 },
        SchemeSpec::Poly { s: 1, m: 2 },
    ] {
        let label = scheme.label();
        let (log, _) = train(config(10, scheme, 120, lr), &train_ds, Some(&test_ds)).unwrap();
        aucs.push((label, log.final_auc().unwrap()));
    }
    for (label, auc) in &aucs {
        assert!(*auc > 0.65, "{label}: AUC {auc}");
    }
    let max = aucs.iter().map(|(_, a)| *a).fold(0.0f64, f64::max);
    let min = aucs.iter().map(|(_, a)| *a).fold(1.0f64, f64::min);
    assert!(max - min < 0.06, "scheme AUCs diverged: {aucs:?}");
}

#[test]
fn coded_scheme_transmits_m_times_less() {
    let (train_ds, _) = dataset(800, 211);
    let lr = 4.0 / train_ds.rows as f32;
    let (log_m1, _) = train(
        config(5, SchemeSpec::Poly { s: 2, m: 1 }, 10, lr),
        &train_ds,
        None,
    )
    .unwrap();
    let (log_m2, _) = train(
        config(5, SchemeSpec::Poly { s: 1, m: 2 }, 10, lr),
        &train_ds,
        None,
    )
    .unwrap();
    let f1 = log_m1.total_floats_transmitted() as f64;
    let f2 = log_m2.total_floats_transmitted() as f64;
    // Per-worker payload halves with m=2 (same padded l => exactly 2x).
    let ratio = f1 / f2;
    assert!((ratio - 2.0).abs() < 0.05, "comm ratio {ratio}");
}

#[test]
fn straggler_patterns_vary_across_iterations() {
    // The virtual cluster must actually rotate stragglers; a fixed
    // responder set would make the decoder cache hide decode bugs.
    let (train_ds, _) = dataset(600, 221);
    let lr = 4.0 / train_ds.rows as f32;
    let (log, _) = train(
        config(8, SchemeSpec::Poly { s: 2, m: 2 }, 40, lr),
        &train_ds,
        None,
    )
    .unwrap();
    let distinct: std::collections::HashSet<Vec<usize>> =
        log.records.iter().map(|r| r.responders.clone()).collect();
    assert!(
        distinct.len() > 5,
        "expected varied responder sets, got {}",
        distinct.len()
    );
    assert!(log.records.iter().all(|r| r.responders.len() == 6));
}

#[test]
fn identical_seeds_reproduce_identical_runs() {
    let (train_ds, test_ds) = dataset(600, 231);
    let lr = 4.0 / train_ds.rows as f32;
    let cfg = config(6, SchemeSpec::Poly { s: 1, m: 2 }, 30, lr);
    let (log_a, beta_a) = train(cfg.clone(), &train_ds, Some(&test_ds)).unwrap();
    let (log_b, beta_b) = train(cfg, &train_ds, Some(&test_ds)).unwrap();
    assert_eq!(beta_a, beta_b, "parameters must be bit-identical");
    assert_eq!(log_a.total_sim_time(), log_b.total_sim_time());
    let resp_a: Vec<_> = log_a.records.iter().map(|r| r.responders.clone()).collect();
    let resp_b: Vec<_> = log_b.records.iter().map(|r| r.responders.clone()).collect();
    assert_eq!(resp_a, resp_b);
}

/// Backend wrapper that permanently fails a chosen set of workers —
/// failure injection for the coordinator's straggler-tolerance path.
struct FailingBackend {
    inner: RustBackend,
    dead: Vec<usize>,
}

impl ComputeBackend for FailingBackend {
    fn dim(&self) -> usize {
        self.inner.dim()
    }
    fn out_dim(&self) -> usize {
        self.inner.out_dim()
    }
    fn encoded_gradient(
        &self,
        worker: usize,
        iter: usize,
        beta: &[f32],
        out: &mut Vec<f32>,
    ) -> anyhow::Result<()> {
        if self.dead.contains(&worker) {
            anyhow::bail!("injected failure on worker {worker}");
        }
        self.inner.encoded_gradient(worker, iter, beta, out)
    }
}

#[test]
fn training_survives_injected_worker_failure() {
    // One permanently-failed worker with s = 1: training must proceed and
    // the failed worker must never appear among the responders.
    let (train_ds, _) = dataset(500, 301);
    let scheme = SchemeSpec::Poly { s: 1, m: 2 };
    let code = scheme.build(5).unwrap();
    let padded = SyntheticCategorical::pad_to_multiple(&train_ds, 2);
    let inner = RustBackend::new(code.as_ref(), &padded).unwrap();
    let backend: Arc<dyn ComputeBackend> =
        Arc::new(FailingBackend { inner, dead: vec![3] });
    let cfg = TrainConfig {
        n: 5,
        scheme,
        iters: 15,
        opt: OptChoice::Sgd { lr: 4.0 / padded.rows as f32 },
        eval_every: 5,
        delays: Some(DelayParams::table_vi1()),
        mode: ExecutionMode::Virtual,
        seed: 0xdead,
        minibatch: None,
        quorum: None,
        fleet: None,
        chaos: None,
    };
    let mut tr = Trainer::with_backend(cfg, code, backend, &padded, None).unwrap();
    let log = tr.run().unwrap();
    assert_eq!(log.records.len(), 15);
    for r in &log.records {
        assert_eq!(r.responders.len(), 4);
        assert!(!r.responders.contains(&3), "dead worker used: {:?}", r.responders);
    }
    let first = log.records[0].loss.unwrap();
    let last = log.final_loss().unwrap();
    assert!(last < first, "loss must still decrease: {first} -> {last}");
}

#[test]
fn too_many_failures_error_cleanly() {
    // Two failed workers with s = 1 exceeds the tolerance — without a
    // chaos config authorizing degradation the trainer must fail loudly
    // rather than decode garbage.
    let (train_ds, _) = dataset(500, 311);
    let scheme = SchemeSpec::Poly { s: 1, m: 2 };
    let code = scheme.build(5).unwrap();
    let padded = SyntheticCategorical::pad_to_multiple(&train_ds, 2);
    let inner = RustBackend::new(code.as_ref(), &padded).unwrap();
    let backend: Arc<dyn ComputeBackend> =
        Arc::new(FailingBackend { inner, dead: vec![1, 3] });
    let cfg = TrainConfig {
        n: 5,
        scheme,
        iters: 3,
        opt: OptChoice::Sgd { lr: 0.01 },
        eval_every: 3,
        delays: None,
        mode: ExecutionMode::Virtual,
        seed: 0xdead,
        minibatch: None,
        quorum: None,
        fleet: None,
        chaos: None,
    };
    let mut tr = Trainer::with_backend(cfg, code, backend, &padded, None).unwrap();
    let err = tr.run().unwrap_err();
    assert!(
        err.to_string().contains("wait rule unsatisfied"),
        "unexpected error: {err}"
    );
}

#[test]
fn minibatch_sgd_trains_and_transmits_same() {
    // §II: the scheme applies to mini-batch SGD unchanged — the coded
    // payload size is independent of the batch size.
    let (train_ds, test_ds) = dataset(1200, 321);
    let mut cfg = config(6, SchemeSpec::Poly { s: 1, m: 2 }, 80, 8.0 / 900.0);
    cfg.minibatch = Some(0.25);
    let (log, _) = train(cfg.clone(), &train_ds, Some(&test_ds)).unwrap();
    assert!(log.final_auc().unwrap() > 0.65, "minibatch AUC {:?}", log.final_auc());
    // same floats/iter as full batch
    cfg.minibatch = None;
    let (log_full, _) = train(cfg, &train_ds, Some(&test_ds)).unwrap();
    assert_eq!(
        log.total_floats_transmitted(),
        log_full.total_floats_transmitted()
    );
}

#[test]
fn hetero_beats_uniform_poly_on_bimodal_fleet_predicted_and_realized() {
    // The heterogeneous subsystem's acceptance check: on a bimodal fleet
    // the group-based scheme must (a) be *predicted* faster than
    // uniform-load tight poly by the simulator, (b) *realize* a faster
    // mean iteration on the virtual cluster, and (c) realize what the
    // simulator predicted (the two share the delay scaling and the
    // stopping rule, so they must agree up to Monte-Carlo noise).
    use gradcode::coding::HeteroCode;
    use gradcode::simulator::hetero::{expected_fleet_time, expected_hetero_time};
    use gradcode::simulator::SpeedProfile;

    let (train_ds, _) = dataset(1500, 401);
    let lr = 5.0 / train_ds.rows as f32;
    let (n, s, m) = (10usize, 1usize, 2usize);
    let p = DelayParams::ec2_fit();
    let profile = SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 };
    let speeds = profile.speeds(n);
    let iters = 150;

    // (a) model-side comparison
    let code = HeteroCode::from_speeds(n, s, m, &speeds).unwrap();
    let predicted_hetero = expected_hetero_time(&p, &code);
    let predicted_uniform = expected_fleet_time(&p, &speeds, s + m, s, m);
    assert!(
        predicted_hetero < predicted_uniform,
        "model must favor hetero: {predicted_hetero} vs {predicted_uniform}"
    );

    // (b) realized comparison on the virtual cluster
    let mk = |scheme, fleet| TrainConfig {
        n,
        scheme,
        iters,
        opt: OptChoice::Nag { lr, momentum: 0.9 },
        eval_every: iters,
        delays: Some(p),
        mode: ExecutionMode::Virtual,
        seed: 0x4e7,
        minibatch: None,
        quorum: None,
        fleet,
        chaos: None,
    };
    let (log_hetero, _) = train(
        mk(SchemeSpec::Hetero { s, m, profile: profile.clone() }, None),
        &train_ds,
        None,
    )
    .unwrap();
    let (log_poly, _) = train(
        mk(SchemeSpec::Poly { s, m }, Some(profile)),
        &train_ds,
        None,
    )
    .unwrap();
    let realized_hetero = log_hetero.mean_iteration_sim_time();
    let realized_poly = log_poly.mean_iteration_sim_time();
    assert!(
        realized_hetero < realized_poly,
        "virtual cluster must favor hetero: {realized_hetero} vs {realized_poly}"
    );

    // (c) prediction ↔ realization agreement (150 iterations of MC noise)
    let rel_h = (realized_hetero - predicted_hetero).abs() / predicted_hetero;
    assert!(
        rel_h < 0.15,
        "hetero: realized {realized_hetero} vs predicted {predicted_hetero} ({rel_h:.3})"
    );
    let rel_u = (realized_poly - predicted_uniform).abs() / predicted_uniform;
    assert!(
        rel_u < 0.15,
        "uniform: realized {realized_poly} vs predicted {predicted_uniform} ({rel_u:.3})"
    );
}

#[test]
fn random_scheme_handles_extra_responders() {
    // §IV decode uses ALL responders (pseudo-inverse), so even when
    // every worker responds the decode must stay exact.
    let (train_ds, test_ds) = dataset(800, 241);
    let lr = 4.0 / train_ds.rows as f32;
    let cfg = TrainConfig {
        n: 8,
        scheme: SchemeSpec::Random { s: 2, m: 2, seed: 5 },
        iters: 60,
        opt: OptChoice::Nag { lr, momentum: 0.9 },
        eval_every: 15,
        delays: None, // no stragglers: all 8 respond, decode from 8 > n-s
        mode: ExecutionMode::Virtual,
        seed: 0xbeef,
        minibatch: None,
        quorum: None,
        fleet: None,
        chaos: None,
    };
    let (log, _) = train(cfg, &train_ds, Some(&test_ds)).unwrap();
    let first = log.records[0].loss.unwrap();
    let last = log.final_loss().unwrap();
    assert!(last < first * 0.9, "{first} -> {last}");
}
