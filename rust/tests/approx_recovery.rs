//! Integration: the approximate (partial-recovery) regime end-to-end —
//! exactness at full quorum, trainer behavior under a partial quorum,
//! and agreement between the §VI simulator's predictions (runtime and
//! residual) and what a seeded virtual cluster actually measures.

use gradcode::coding::ApproxCode;
use gradcode::coordinator::{train, ExecutionMode, OptChoice, SchemeSpec, TrainConfig};
use gradcode::data::{train_test_split, CategoricalConfig, SyntheticCategorical};
use gradcode::simulator::approx::{expected_coeff_residual, expected_runtime_at_quorum};
use gradcode::simulator::{DelayParams, VirtualCluster};

fn dataset(rows: usize, seed: u64) -> (gradcode::data::DenseDataset, gradcode::data::DenseDataset) {
    let gen = SyntheticCategorical::new(CategoricalConfig::default(), seed);
    let ds = gen.generate(rows, seed + 1);
    train_test_split(&ds, 0.25, seed + 2)
}

fn config(n: usize, scheme: SchemeSpec, iters: usize, lr: f32, seed: u64) -> TrainConfig {
    TrainConfig {
        n,
        scheme,
        iters,
        opt: OptChoice::Nag { lr, momentum: 0.9 },
        eval_every: 10,
        delays: Some(DelayParams::table_vi1()),
        mode: ExecutionMode::Virtual,
        seed,
        minibatch: None,
        quorum: None,
        fleet: None,
        chaos: None,
    }
}

#[test]
fn full_quorum_approx_matches_uncoded_trajectory() {
    // At quorum = 1.0 the partial decoder is exact, so approximate
    // training must follow the uncoded trajectory (same gradients, same
    // clockless optimizer path).
    let (train_ds, _) = dataset(400, 401);
    let lr = 4.0 / train_ds.rows as f32;
    let mk = |scheme| {
        let mut cfg = config(4, scheme, 25, lr, 9);
        cfg.delays = None;
        cfg
    };
    let (_, beta_approx) =
        train(mk(SchemeSpec::Approx { d: 2, quorum: 1.0 }), &train_ds, None).unwrap();
    let (_, beta_naive) = train(mk(SchemeSpec::Uncoded), &train_ds, None).unwrap();
    let max_diff = beta_approx
        .iter()
        .zip(&beta_naive)
        .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
    let scale = beta_naive.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
    assert!(
        max_diff / scale < 1e-2,
        "trajectory divergence {max_diff} (scale {scale})"
    );
}

#[test]
fn partial_quorum_cuts_iteration_time_and_reports_residual() {
    let (train_ds, test_ds) = dataset(1200, 411);
    let lr = 6.0 / train_ds.rows as f32;
    let (log_full, _) = train(
        config(10, SchemeSpec::Approx { d: 3, quorum: 1.0 }, 60, lr, 13),
        &train_ds,
        Some(&test_ds),
    )
    .unwrap();
    let (log_part, _) = train(
        config(10, SchemeSpec::Approx { d: 3, quorum: 0.6 }, 60, lr, 13),
        &train_ds,
        Some(&test_ds),
    )
    .unwrap();
    // the quorum is respected every iteration
    assert!(log_full.records.iter().all(|r| r.responders.len() == 10));
    assert!(log_part.records.iter().all(|r| r.responders.len() == 6));
    // proceeding at 6 of 10 must be faster on the simulated clock
    assert!(
        log_part.mean_iteration_sim_time() < log_full.mean_iteration_sim_time(),
        "partial {} vs full {}",
        log_part.mean_iteration_sim_time(),
        log_full.mean_iteration_sim_time()
    );
    // residual accounting: reported every iteration, ~0 at full quorum
    assert!(log_part.records.iter().all(|r| r.decode_residual.is_some()));
    assert!(log_full.mean_decode_residual().unwrap() < 1e-9);
    assert!(log_part.mean_decode_residual().unwrap() >= 0.0);
    // approximate training must still learn
    let first = log_part.records[0].loss.unwrap();
    let last = log_part.final_loss().unwrap();
    assert!(last < first, "loss must decrease: {first} -> {last}");
}

#[test]
fn exact_schemes_report_no_residual() {
    let (train_ds, _) = dataset(500, 421);
    let lr = 4.0 / train_ds.rows as f32;
    let (log, _) = train(
        config(5, SchemeSpec::Poly { s: 1, m: 2 }, 10, lr, 5),
        &train_ds,
        None,
    )
    .unwrap();
    assert!(log.records.iter().all(|r| r.decode_residual.is_none()));
    assert_eq!(log.mean_decode_residual(), None);
}

#[test]
fn simulator_residual_matches_virtual_cluster_measurement() {
    // Under assumptions 1-3 the r fastest workers are a uniform r-subset,
    // so the simulator's Monte-Carlo expectation over uniform subsets
    // must match the mean residual measured on the virtual cluster's
    // actual responder sets.
    let p = DelayParams::table_vi1();
    let (n, d, r) = (8usize, 2usize, 5usize);
    let code = ApproxCode::new(n, d, r).unwrap();
    let mut vc = VirtualCluster::new(&p, n, d, n - r, 1, 77);
    let iters = 3000;
    let measured: f64 = (0..iters)
        .map(|_| {
            let sample = vc.sample_iteration();
            let responders = sample.responders(r);
            code.partial_decode(&responders).unwrap().coeff_residual
        })
        .sum::<f64>()
        / iters as f64;
    let predicted = expected_coeff_residual(&code, r, 4000, 78);
    assert!(
        predicted > 0.05,
        "test needs a regime with a nontrivial residual, got {predicted}"
    );
    let rel = (measured - predicted).abs() / predicted;
    assert!(
        rel < 0.15,
        "measured {measured:.4} vs predicted {predicted:.4} (rel {rel:.3})"
    );
}

#[test]
fn simulator_runtime_prediction_matches_virtual_cluster() {
    // The r-th order-statistic quadrature must agree with Monte-Carlo
    // simulation of the same delay model (the quorum analogue of the
    // existing Eq. 28/29 cross-check).
    let p = DelayParams::table_vi1();
    for (n, d, r) in [(8usize, 3usize, 5usize), (10, 2, 4), (10, 3, 10)] {
        let mut vc = VirtualCluster::new(&p, n, d, n - r, 1, 42);
        let mc = vc.mean_iteration_time(60_000);
        let exact = expected_runtime_at_quorum(&p, n, d, r);
        let rel = (mc - exact).abs() / exact;
        assert!(
            rel < 0.02,
            "(n={n},d={d},r={r}): MC {mc:.3} vs quadrature {exact:.3}"
        );
    }
}

#[test]
fn smaller_quorum_never_slows_the_virtual_clock() {
    // On identical seeds the r-th arrival is monotone in r per
    // iteration, hence also on average.
    let p = DelayParams::table_vi1();
    let n = 10;
    let mut prev = 0.0;
    for r in [2usize, 5, 8, 10] {
        let mut vc = VirtualCluster::new(&p, n, 3, n - r, 1, 11);
        let t = vc.mean_iteration_time(10_000);
        assert!(t > prev, "mean time must grow with the quorum: r={r} gives {t}");
        prev = t;
    }
}

#[test]
fn trainer_residuals_match_direct_partial_decode() {
    // The residual the trainer records per iteration must be exactly the
    // scheme's partial_decode residual for that responder set.
    let (train_ds, _) = dataset(600, 431);
    let lr = 4.0 / train_ds.rows as f32;
    let (log, _) = train(
        config(8, SchemeSpec::Approx { d: 2, quorum: 0.5 }, 30, lr, 21),
        &train_ds,
        None,
    )
    .unwrap();
    let code = ApproxCode::new(8, 2, 4).unwrap();
    for rec in &log.records {
        assert_eq!(rec.responders.len(), 4);
        let want = code.partial_decode(&rec.responders).unwrap().coeff_residual;
        let got = rec.decode_residual.unwrap();
        assert!(
            (got - want).abs() < 1e-12,
            "iter {}: recorded {got} vs recomputed {want}",
            rec.iter
        );
    }
    // with half the workers missing some iterations must be inexact
    assert!(
        log.records.iter().any(|r| r.decode_residual.unwrap() > 1e-9),
        "quorum 4 of 8 with d=2 should hit non-covering responder sets"
    );
}
