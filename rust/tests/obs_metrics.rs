//! Integration: the live metrics layer end to end — the acceptance
//! properties of the issue:
//!
//! - the Prometheus exposition escapes label values, HELP text, and
//!   metric names exactly per the text format (including non-finite
//!   sample values);
//! - a loopback TCP training run scraped *mid-run* through a real
//!   [`ScrapeServer`] reports wire counters identical to the master's
//!   [`WireCounters`], and the per-worker fleet gauges carried in the
//!   v4 Result metrics block match what each worker actually served;
//! - the flight ring wraps at capacity keeping the newest events, and a
//!   run that aborts through the degradation ladder dumps the ring to
//!   the `GRADCODE_FLIGHT_DUMP` path as parseable JSONL;
//! - the health watchdog flags a fleet whose realized straggler regime
//!   is bimodal while the declared profile is uniform, and stays silent
//!   when the declaration is correct (both sides driven by the §VI
//!   model, so the test is fully deterministic).

use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

use gradcode::chaos::{ChaosConfig, FaultKind, FaultPlan};
use gradcode::coordinator::remote::{decode_gather, scheme_from_setup};
use gradcode::coordinator::wire::{Message, Setup, SCHEME_POLY};
use gradcode::coordinator::{run_worker, RemoteMaster, SchemeSpec, TrainConfig, Trainer};
use gradcode::data::{CategoricalConfig, DenseDataset, SyntheticCategorical};
use gradcode::obs::flight::{self, FlightRecorder};
use gradcode::obs::metrics::{escape_help, escape_label, metric_name};
use gradcode::obs::{HealthConfig, HealthStatus, HealthWatchdog, MetricsRegistry, Recorder};
use gradcode::simulator::{expected_wait_time, DelayParams};
use gradcode::testkit::with_watchdog;

fn dataset(rows: usize, seed: u64) -> DenseDataset {
    let gen = SyntheticCategorical::new(CategoricalConfig::default(), seed);
    gen.generate(rows, seed + 1)
}

fn free_addr() -> std::net::SocketAddr {
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = l.local_addr().unwrap();
    drop(l);
    addr
}

/// GET /metrics from a live [`ScrapeServer`], returning the body.
fn scrape(addr: std::net::SocketAddr) -> String {
    let mut s = TcpStream::connect(addr).expect("scrape endpoint accepts");
    s.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut resp = String::new();
    s.read_to_string(&mut resp).unwrap();
    let (head, body) = resp.split_once("\r\n\r\n").expect("HTTP response has a header block");
    assert!(head.starts_with("HTTP/1.0 200 OK"), "{head}");
    assert!(head.contains("text/plain; version=0.0.4"), "{head}");
    body.to_string()
}

/// The value of one exposition line: `series` is the full sample name
/// including any `{label="..."}` block.
fn sample(body: &str, series: &str) -> Option<f64> {
    body.lines().find_map(|l| l.strip_prefix(series)?.strip_prefix(' ')?.trim().parse().ok())
}

/// Escaping acceptance: names, label values, HELP text, and non-finite
/// values all render per the exposition format.
#[test]
fn exposition_escapes_names_labels_and_values() {
    assert_eq!(metric_name("wire.tx_frames"), "gradcode_wire_tx_frames");
    assert_eq!(metric_name("phase latency (µs)"), "gradcode_phase_latency___s_");
    assert_eq!(escape_label("C:\\tmp\n\"x\""), "C:\\\\tmp\\n\\\"x\\\"");
    // HELP escapes backslash and newline but leaves quotes alone
    assert_eq!(escape_help("a\\b\n\"q\""), "a\\\\b\\n\"q\"");

    let rec = Recorder::enabled();
    rec.set("bad name\nwith\\newline", 7);
    let registry = MetricsRegistry::new(&rec);
    registry.set_gauge("queue depth", &[("path", "a\\b\n\"c\"")], f64::INFINITY);
    registry.set_gauge("nan gauge", &[], f64::NAN);
    registry.inc("scrapes", &[], 3);
    registry.observe("gather.lag", &[], 0.5);
    let text = registry.render();

    // the hostile recorder counter name is sanitized in the series line
    // and escaped in its HELP line
    assert!(text.contains("gradcode_bad_name_with_newline 7"), "{text}");
    assert!(text.contains("recorder counter `bad name\\nwith\\\\newline`"), "{text}");
    assert!(text.contains("# TYPE gradcode_queue_depth gauge"), "{text}");
    assert!(
        text.contains("gradcode_queue_depth{path=\"a\\\\b\\n\\\"c\\\"\"} +Inf"),
        "{text}"
    );
    assert!(text.contains("gradcode_nan_gauge NaN"), "{text}");
    assert!(text.contains("# TYPE gradcode_scrapes counter"), "{text}");
    assert!(text.contains("gradcode_scrapes 3"), "{text}");
    assert!(text.contains("# TYPE gradcode_gather_lag summary"), "{text}");
    assert!(text.contains("gradcode_gather_lag_count 1"), "{text}");
    assert!(text.contains("gradcode_gather_lag{quantile=\"0.5\"}"), "{text}");
}

/// Acceptance: a loopback TCP run scraped mid-run serves wire counters
/// *identical* to the master's [`WireCounters`], the fleet gauges from
/// the Result metrics block match what each worker served, and the
/// shutdown frames show up in a post-shutdown scrape with exactly
/// `n × |Shutdown frame|` more tx bytes.
#[test]
fn live_scrape_during_tcp_train_matches_wire_counters() {
    with_watchdog(Duration::from_secs(60), "live_scrape_during_tcp_train", || {
        let n = 3u32;
        let iters = 5u64;
        // s = 0 so the quorum is the whole fleet: every Result is
        // drained every iteration and the fleet gauges are exact.
        let setup = Setup::homogeneous(n, 1, 0, 1, SCHEME_POLY, 1, 777, n * 16, 64);
        let addr = free_addr();
        // Workers first (they retry while the master's listener binds).
        let workers: Vec<_> = (0..n as usize)
            .map(|w| {
                std::thread::spawn(move || -> anyhow::Result<usize> {
                    for _ in 0..400 {
                        match run_worker(addr, w) {
                            Ok(served) => return Ok(served),
                            Err(e) if e.to_string().contains("connecting to master") => {
                                std::thread::sleep(Duration::from_millis(10));
                            }
                            Err(e) => return Err(e),
                        }
                    }
                    anyhow::bail!("master listener never came up")
                })
            })
            .collect();

        let mut master = RemoteMaster::listen(addr, setup.clone()).unwrap();
        let rec = Recorder::enabled();
        master.set_recorder(&rec);
        let registry = MetricsRegistry::new(&rec);
        let srv = registry.serve("127.0.0.1:0").unwrap();

        let code = scheme_from_setup(&setup).unwrap();
        let mut cache = HashMap::new();
        let beta = vec![0.0f32; setup.dim as usize];
        for iter in 0..iters {
            let gather = master.run_iteration(iter, &beta).unwrap();
            assert!(gather.complete);
            assert_eq!(gather.results.len(), n as usize);
            let grad = decode_gather(code.as_ref(), &gather, &mut cache).unwrap();
            assert_eq!(grad.len(), setup.dim as usize);

            // Mid-run scrape: the gauges exported inside run_iteration
            // must equal the live counters exactly — not eventually.
            if iter == 1 {
                let wc = *master.wire_counters();
                let body = scrape(srv.addr());
                for (series, want) in [
                    ("gradcode_wire_tx_frames", wc.tx_frames),
                    ("gradcode_wire_tx_bytes", wc.tx_bytes),
                    ("gradcode_wire_rx_frames", wc.rx_frames),
                    ("gradcode_wire_rx_bytes", wc.rx_bytes),
                    ("gradcode_wire_corrupt_rejects", wc.corrupt_rejects),
                ] {
                    assert_eq!(
                        sample(&body, series).unwrap_or(-1.0) as u64,
                        want,
                        "mid-run {series}"
                    );
                }
                // the fleet gauges ride the v4 Result metrics block:
                // after the iter-1 Results, every worker has served 2
                for w in 0..n {
                    let series = format!("gradcode_fleet_iters_served{{worker=\"{w}\"}}");
                    assert_eq!(sample(&body, &series), Some(2.0), "{series}");
                }
            }
        }

        // End-of-run scrape: same identity against the final totals.
        let wc = *master.wire_counters();
        assert_eq!(wc.corrupt_rejects, 0);
        let body = scrape(srv.addr());
        assert_eq!(sample(&body, "gradcode_wire_tx_frames"), Some(wc.tx_frames as f64));
        assert_eq!(sample(&body, "gradcode_wire_tx_bytes"), Some(wc.tx_bytes as f64));
        assert_eq!(sample(&body, "gradcode_wire_rx_frames"), Some(wc.rx_frames as f64));
        assert_eq!(sample(&body, "gradcode_wire_rx_bytes"), Some(wc.rx_bytes as f64));
        for w in 0..n {
            for (field, want) in [("iters_served", iters as f64), ("faults", 0.0)] {
                let series = format!("gradcode_fleet_{field}{{worker=\"{w}\"}}");
                assert_eq!(sample(&body, &series), Some(want), "{series}");
            }
            // byte counters are platform-independent but nonzero
            let tx = sample(&body, &format!("gradcode_fleet_tx_bytes{{worker=\"{w}\"}}"));
            assert!(tx.unwrap() > 0.0, "worker {w} reported no tx bytes");
        }
        // one # TYPE per family even with n labeled fleet samples
        let type_lines = body
            .lines()
            .filter(|l| *l == "# TYPE gradcode_fleet_iters_served gauge")
            .count();
        assert_eq!(type_lines, 1);

        // Shutdown sends exactly one more frame per worker; the
        // re-exported gauges account for every byte of it.
        let shutdown_len = Message::Shutdown.encode().len() as u64;
        master.shutdown();
        let body = scrape(srv.addr());
        assert_eq!(
            sample(&body, "gradcode_wire_tx_frames"),
            Some((wc.tx_frames + n as u64) as f64)
        );
        assert_eq!(
            sample(&body, "gradcode_wire_tx_bytes"),
            Some((wc.tx_bytes + n as u64 * shutdown_len) as f64)
        );
        assert_eq!(sample(&body, "gradcode_wire_rx_frames"), Some(wc.rx_frames as f64));

        assert!(srv.hits() >= 3, "served {} scrapes", srv.hits());
        srv.shutdown();
        for (w, h) in workers.into_iter().enumerate() {
            let served = h.join().unwrap().unwrap();
            assert_eq!(served as u64, iters, "worker {w} served every iteration");
        }
    });
}

/// The ring keeps the newest `capacity` events and never loses count.
#[test]
fn flight_ring_wraps_keeping_newest_events() {
    let ring = FlightRecorder::with_capacity(8);
    for i in 0..50u64 {
        ring.record("iteration", Some(i as usize % 4), Some(i), &format!("step {i}"));
    }
    assert_eq!(ring.len(), 8);
    assert_eq!(ring.capacity(), 8);
    assert_eq!(ring.total_recorded(), 50);
    let seqs: Vec<u64> = ring.snapshot().iter().map(|e| e.seq).collect();
    assert_eq!(seqs, (42u64..50).collect::<Vec<_>>());
    // round-trip through the dump format preserves the snapshot
    let text = flight::render_jsonl(&ring.snapshot());
    assert_eq!(flight::parse_dump(&text).unwrap(), ring.snapshot());
}

/// Acceptance: a run that aborts through the degradation ladder (every
/// worker drops every result, so every iteration lands on the stale
/// rung) writes the flight ring to the `GRADCODE_FLIGHT_DUMP` path,
/// and the dump holds the iteration breadcrumbs and fault events that
/// led up to the abort.
#[test]
fn ladder_abort_dumps_flight_ring_to_env_path() {
    let dir = std::env::temp_dir().join(format!("gradcode_obs_metrics_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let path = dir.join("abort_dump.jsonl");
    std::env::set_var(flight::DUMP_ENV, &path);

    let n = 4;
    let iters = 20;
    let mut plan = FaultPlan::new(n);
    for w in 0..n {
        for it in 0..iters as u64 {
            plan.schedule(w, it, FaultKind::Drop);
        }
    }
    let mut cfg = TrainConfig::quick(n, SchemeSpec::Poly { s: 1, m: 1 }, iters);
    cfg.chaos = Some(ChaosConfig::new(plan));
    let ds = dataset(200, 0x0b60);
    let mut tr = Trainer::new(cfg, &ds, None).unwrap();
    let err = tr.run();
    std::env::remove_var(flight::DUMP_ENV);
    let err = err.expect_err("an all-drop fleet must abort via the stale ladder");
    assert!(err.to_string().contains("consecutive stale"), "{err}");

    let text = std::fs::read_to_string(&path).expect("the abort dumped the flight ring");
    let events = flight::parse_dump(&text).expect("dump is valid JSONL");
    assert!(!events.is_empty());
    assert!(events.len() <= flight::DEFAULT_CAPACITY);
    for w in events.windows(2) {
        assert!(w[0].seq < w[1].seq, "dump is in sequence order");
    }
    assert!(events.iter().any(|e| e.kind == "iteration"), "trainer breadcrumbs present");
    assert!(
        events.iter().any(|e| e.kind == "deadline" || e.kind == "rung"),
        "fault-log events mirrored into the ring"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: the watchdog flags a mis-declared fleet and stays silent
/// on a correct declaration. Both the declared expectation and the
/// realized times come from [`expected_wait_time`], so the test pins the
/// detection logic without sampling noise: the "realized" fleet is
/// bimodal (half the workers 4× slower) while the declaration is
/// uniform.
#[test]
fn watchdog_flags_bimodal_fleet_declared_uniform_and_accepts_correct_declaration() {
    let n = 8;
    let (s, m) = (2, 2);
    let params = DelayParams { lambda1: 0.8, t1: 1.6, lambda2: 0.1, t2: 0.5 };
    let work = vec![(s + m) as f64; n];
    let uniform = vec![1.0; n];
    let bimodal: Vec<f64> = (0..n).map(|w| if w < n / 2 { 1.0 } else { 0.25 }).collect();
    let groups = vec![((0..n).collect::<Vec<_>>(), n - s)];
    let declared = expected_wait_time(&params, m, &work, &uniform, &groups);
    let realized = expected_wait_time(&params, m, &work, &bimodal, &groups);
    let cfg = HealthConfig { window: 5, threshold: 0.5 };
    // premise: waiting for n-s of a half-4×-slow fleet really does blow
    // the 50% budget — otherwise the scenario would not discriminate
    assert!(
        (realized - declared) / declared > cfg.threshold,
        "bimodal wait {realized:.4}s vs uniform {declared:.4}s is not a regime shift"
    );

    let mut dog = HealthWatchdog::new(declared, cfg);
    assert_eq!(dog.status(), HealthStatus::Unknown);
    let mut warning = None;
    for i in 0..cfg.window as u64 {
        warning = dog.observe(i, realized);
    }
    let warning = warning.expect("a full mis-declared window fires");
    assert!(warning.contains("re-plan"), "{warning}");
    assert_eq!(dog.status(), HealthStatus::Degraded);
    assert_eq!(dog.status().gauge(), 0);
    assert_eq!(dog.warnings().len(), 1);
    // the gauge lands in the recorder under the stable name
    let rec = Recorder::enabled();
    dog.export(&rec);
    assert!(rec.counters().contains(&("health_status".to_string(), 0)));

    // correctly-declared fleet: same realized times, matching model
    let mut honest = HealthWatchdog::new(realized, cfg);
    for i in 0..(3 * cfg.window) as u64 {
        assert!(honest.observe(i, realized).is_none(), "honest declaration stays silent");
    }
    assert_eq!(honest.status(), HealthStatus::Healthy);
    assert_eq!(honest.status().gauge(), 1);
    assert!(honest.warnings().is_empty());
}
