//! Seeded round-trip and mutation fuzzing of the v4 wire protocol.
//!
//! Three layers of guarantee, each over randomized frames of every
//! [`Message`] variant:
//! - valid frames round-trip byte-exactly (decode ∘ encode = id);
//! - any single-bit flip, any truncation, and any oversized length
//!   prefix produce a typed [`WireError`] — never a panic, never a
//!   giant allocation;
//! - frames whose payload is mutated *and* resealed with a fresh CRC
//!   exercise the decode-level validation (tags, list bounds, f32
//!   alignment, trailing bytes) and still never panic.
//!
//! Handshake-level MAGIC/version mismatches are covered against a real
//! [`RemoteMaster`] listener.
//!
//! All cases derive from the testkit root seed — a failure prints a
//! `TESTKIT_SEED=…` reproducer line.

use gradcode::coordinator::wire::{
    crc32, Message, Setup, WireError, WorkerMetrics, MAGIC, SCHEME_POLY,
};
use gradcode::coordinator::RemoteMaster;
use gradcode::rngs::{Pcg64, Rng};
use gradcode::testkit::{check, CaseResult, Config};

/// A random message of a random variant. Floats are finite (NaN would
/// break the `PartialEq` round-trip check without testing anything about
/// the wire format) and Setup list lengths respect the `<= n` bound the
/// decoder enforces.
fn random_message(rng: &mut Pcg64) -> Message {
    let f32s = |rng: &mut Pcg64, len: usize| -> Vec<f32> {
        (0..len).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect()
    };
    match rng.next_index(5) {
        0 => Message::Hello {
            magic: rng.next_u64() as u32,
            worker_id: rng.next_bounded(1024) as u32,
        },
        1 => {
            let n = 1 + rng.next_index(32) as u32;
            let with_lists = rng.next_f64() < 0.5;
            let list_len = if with_lists { rng.next_index(n as usize + 1) } else { 0 };
            Message::Setup(Setup {
                n,
                d: 1 + rng.next_bounded(n as u64) as u32,
                s: rng.next_bounded(n as u64) as u32,
                m: 1 + rng.next_bounded(4) as u32,
                scheme_kind: rng.next_index(6) as u8,
                scheme_seed: rng.next_u64(),
                data_seed: rng.next_u64(),
                rows: rng.next_bounded(1 << 20) as u32,
                dim: rng.next_bounded(1 << 16) as u32,
                quorum: rng.next_bounded(n as u64 + 1) as u32,
                loads: (0..list_len).map(|_| rng.next_bounded(64) as u32).collect(),
                speeds_milli: (0..list_len)
                    .map(|_| 1 + rng.next_bounded(8000) as u32)
                    .collect(),
            })
        }
        2 => {
            let len = rng.next_index(257);
            Message::Task { iter: rng.next_u64(), beta: f32s(rng, len) }
        }
        3 => {
            let failed = rng.next_f64() < 0.2;
            let len = if failed { 0 } else { rng.next_index(257) };
            Message::Result {
                worker: rng.next_bounded(64) as u32,
                iter: rng.next_u64(),
                failed,
                metrics: WorkerMetrics {
                    compute_us: rng.next_u64(),
                    tx_bytes: rng.next_u64(),
                    rx_bytes: rng.next_u64(),
                    faults: rng.next_u64() as u32,
                    iters_served: rng.next_u64() as u32,
                },
                f: f32s(rng, len),
            }
        }
        _ => Message::Shutdown,
    }
}

fn read_frame(frame: &[u8]) -> Result<Message, WireError> {
    let mut cursor = std::io::Cursor::new(frame);
    Message::read_from(&mut cursor)
}

/// decode ∘ encode = id, and re-encoding the decoded message reproduces
/// the original bytes — the frame format has a single canonical form.
#[test]
fn random_frames_roundtrip_byte_exactly() {
    check(
        Config { cases: 256, ..Config::default() },
        "random_frames_roundtrip_byte_exactly",
        random_message,
        |msg| {
            let frame = msg.encode();
            let back = match read_frame(&frame) {
                Ok(m) => m,
                Err(e) => return CaseResult::Fail(format!("valid frame rejected: {e}")),
            };
            if &back != msg {
                return CaseResult::Fail(format!("decoded to a different message: {back:?}"));
            }
            if back.encode() != frame {
                return CaseResult::Fail("re-encode is not byte-identical".into());
            }
            CaseResult::Pass
        },
    );
}

/// CRC32 detects every single-bit error, and a flipped length prefix
/// lands on the size guard or a checksum/EOF failure: any one-bit
/// mutation of a valid frame must yield `Err`, never a panic.
#[test]
fn single_bit_flips_always_error() {
    check(
        Config { cases: 256, ..Config::default() },
        "single_bit_flips_always_error",
        |rng| {
            let msg = random_message(rng);
            let nbits = msg.encode().len() * 8;
            let bit = rng.next_index(nbits);
            (msg, bit)
        },
        |(msg, bit)| {
            let mut frame = msg.encode();
            frame[bit / 8] ^= 1 << (bit % 8);
            match read_frame(&frame) {
                Err(_) => CaseResult::Pass,
                Ok(m) => CaseResult::Fail(format!(
                    "bit {bit} flipped yet the frame decoded to {m:?}"
                )),
            }
        },
    );
}

/// Every strict prefix of every frame fails with `WireError::Io`
/// (truncation = the transport died mid-frame), never a panic.
#[test]
fn every_truncation_errors_as_io() {
    check(
        Config { cases: 64, ..Config::default() },
        "every_truncation_errors_as_io",
        random_message,
        |msg| {
            let frame = msg.encode();
            for cut in 0..frame.len() {
                match read_frame(&frame[..cut]) {
                    Err(WireError::Io(_)) => {}
                    other => {
                        return CaseResult::Fail(format!(
                            "cut at {cut}/{}: expected Io error, got {other:?}",
                            frame.len()
                        ))
                    }
                }
            }
            CaseResult::Pass
        },
    );
}

/// Mutate payload bytes and *reseal* the CRC so the checksum passes:
/// this drives random bytes into the structural decoder, which must
/// return `Ok` or `Corrupt` (the frame arrived whole) and never panic,
/// never report `Io`.
#[test]
fn resealed_mutations_never_panic_and_never_misreport_io() {
    check(
        Config { cases: 256, ..Config::default() },
        "resealed_mutations_never_panic_and_never_misreport_io",
        |rng| {
            let msg = random_message(rng);
            let len = msg.encode().len();
            let edits: Vec<(usize, u8)> = (0..1 + rng.next_index(4))
                .map(|_| (4 + rng.next_index(len - 8), rng.next_u64() as u8))
                .collect();
            (msg, edits)
        },
        |(msg, edits)| {
            let mut frame = msg.encode();
            let plen = u32::from_le_bytes(frame[..4].try_into().unwrap()) as usize;
            for &(pos, byte) in edits {
                // mutate tag or payload only; the length prefix stays
                // honest and the CRC is recomputed below
                if pos < 5 + plen {
                    frame[pos] = byte;
                }
            }
            let crc = crc32(&frame[4..5 + plen]);
            frame[5 + plen..5 + plen + 4].copy_from_slice(&crc.to_le_bytes());
            match read_frame(&frame) {
                Ok(_) | Err(WireError::Corrupt(_)) => CaseResult::Pass,
                Err(WireError::Io(e)) => CaseResult::Fail(format!(
                    "a whole, resealed frame must not be an Io error: {e}"
                )),
            }
        },
    );
}

/// Random oversized length prefixes (above `MAX_PAYLOAD`, up to
/// `u32::MAX`) are rejected by the size guard before any allocation;
/// honest-but-large prefixes over a short stream fail fast at EOF.
#[test]
fn oversized_length_prefixes_error_without_allocation() {
    check(
        Config { cases: 128, ..Config::default() },
        "oversized_length_prefixes_error_without_allocation",
        |rng| {
            let len = (1u64 << 26) + 1 + rng.next_bounded(u32::MAX as u64 - (1 << 26) - 1);
            let tag = rng.next_u64() as u8;
            (len as u32, tag)
        },
        |&(len, tag)| {
            let mut frame = len.to_le_bytes().to_vec();
            frame.push(tag);
            frame.extend_from_slice(&[0u8; 32]);
            match read_frame(&frame) {
                Err(WireError::Corrupt(msg)) if msg.contains("too large") => CaseResult::Pass,
                other => CaseResult::Fail(format!("len {len}: expected size guard, got {other:?}")),
            }
        },
    );
}

/// MAGIC/version mismatch at the handshake: v2 and v3 peers (old
/// magics) and a garbage peer must all fail `RemoteMaster::listen`
/// loudly instead of being accepted or misparsed — a v3 peer's Results
/// would lack the metrics block and misalign the floats.
#[test]
fn stale_magic_fails_the_handshake() {
    for bad_magic in [0x6743_0002u32, 0x6743_0003, 0xdead_beef] {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        drop(l);
        let setup = Setup::homogeneous(1, 1, 0, 1, SCHEME_POLY, 1, 777, 16, 512);
        let master = std::thread::spawn(move || RemoteMaster::listen(addr, setup));
        let peer = std::thread::spawn(move || {
            use std::io::BufWriter;
            // retry (bounded) until the listener is up
            let mut stream = None;
            for _ in 0..500 {
                match std::net::TcpStream::connect(addr) {
                    Ok(s) => {
                        stream = Some(s);
                        break;
                    }
                    Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
                }
            }
            let stream = stream.expect("listener never came up");
            let mut writer = BufWriter::new(stream);
            Message::Hello { magic: bad_magic, worker_id: 0 }.write_to(&mut writer).unwrap();
        });
        let res = master.join().unwrap();
        peer.join().unwrap();
        assert!(
            res.is_err(),
            "magic {bad_magic:#010x} must be rejected at the handshake"
        );
        assert_ne!(bad_magic, MAGIC);
    }
}
