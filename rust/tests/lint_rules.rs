//! Fixture-based coverage for the `gradcode lint` engine: per rule,
//! one violating snippet (must be flagged with the right rule-id and
//! line), one clean snippet, and one `// lint: allow(...)` snippet
//! (must be suppressed, and remain visible in the suppressed list the
//! `--json` summary counts). Plus lexer edge cases and a self-lint
//! gate: the repo itself must be clean against the committed baseline.
//!
//! Every fixture lives inside a string literal, so the snippets are
//! invisible to the linter when it scans this file.

use gradcode::lint::lexer::{lex, TokKind};
use gradcode::lint::{
    fnv1a64, lint_source, lint_tree, Baseline, FileReport, RULE_ADHOC_CHUNK, RULE_FLOAT_REDUCE,
    RULE_LOCK_IO, RULE_PANIC, RULE_WALLCLOCK, RULE_WIRE_DRIFT,
};

/// Lint a fixture under a `rust/src` path label (all rules in scope).
fn lint_src(src: &str) -> FileReport {
    lint_source("rust/src/fixture.rs", src)
}

fn rules_of(report: &FileReport) -> Vec<(&'static str, u32)> {
    report.live.iter().map(|f| (f.rule, f.line)).collect()
}

// ---------------------------------------------------------------- float-reduce

#[test]
fn float_reduce_flags_captured_accumulation() {
    let report = lint_src(
        "
fn f(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    crate::pool::global().map_indexed(4, |c| {
        acc += xs[c];
        0.0f32
    });
    acc
}
",
    );
    assert_eq!(rules_of(&report), vec![(RULE_FLOAT_REDUCE, 5)]);
    assert!(report.live[0].msg.contains("acc"), "msg names the captured base: {}", report.live[0].msg);
}

#[test]
fn float_reduce_flags_chained_fold_on_map_indexed() {
    let report = lint_src(
        "
fn g(pool: &Pool, xs: &[f32]) -> f32 {
    pool.map_indexed(8, |c| xs[c] * 2.0).iter().sum::<f32>()
}
",
    );
    assert_eq!(rules_of(&report), vec![(RULE_FLOAT_REDUCE, 3)]);
    assert!(report.live[0].msg.contains("tree_combine"));
}

#[test]
fn float_reduce_clean_via_tree_combine() {
    let report = lint_src(
        "
fn h(pool: &Pool, xs: &[f32]) -> f32 {
    let parts = pool.map_indexed(4, |c| chunk_sum(xs, c));
    crate::pool::tree_combine(parts, |a, b| a + b).unwrap_or(0.0)
}
",
    );
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
}

#[test]
fn float_reduce_local_scratch_is_not_flagged() {
    // `local` is bound by a `let` inside the closure — accumulating
    // into it is per-chunk scratch, not a cross-chunk reduction.
    let report = lint_src(
        "
fn f(xs: &[f32]) -> Vec<f32> {
    pool.map_indexed(4, |c| {
        let mut local = 0.0f32;
        for x in &xs[c..c + 2] {
            local += *x;
        }
        local
    })
}
",
    );
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
}

#[test]
fn float_reduce_allow_suppresses_and_is_counted() {
    let report = lint_src(
        "
fn f(xs: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    pool.map_indexed(4, |c| {
        // lint: allow(float-reduce-outside-tree) measured prototype; tree_combine lands next pass
        acc += xs[c];
        0.0f32
    });
    acc
}
",
    );
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
    assert_eq!(report.suppressed.len(), 1);
    assert_eq!(report.suppressed[0].rule, RULE_FLOAT_REDUCE);
}

// ------------------------------------------------------------- adhoc-chunk

#[test]
fn chunk_literal_flags_bare_number() {
    let report = lint_src(
        "
fn f(pool: &Pool, buf: &mut [f32]) {
    pool.for_each_chunk_mut(buf, 4096, |c, s| fill(c, s));
}
",
    );
    assert_eq!(rules_of(&report), vec![(RULE_ADHOC_CHUNK, 3)]);
    assert!(report.live[0].msg.contains("4096"));
}

#[test]
fn chunk_literal_clean_with_named_constant() {
    // A literal is fine as long as a *_CHUNK/*_ROWS constant anchors
    // the expression (`2 * ENCODE_CHUNK`), and the definition site of
    // for_each_chunk_mut itself is exempt.
    let report = lint_src(
        "
fn f(pool: &Pool, buf: &mut [f32]) {
    pool.for_each_chunk_mut(buf, 2 * ENCODE_CHUNK, |c, s| fill(c, s));
}
pub fn for_each_chunk_mut(data: &mut [f32], chunk: usize, f: impl Fn(usize, &mut [f32])) {}
",
    );
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
}

#[test]
fn chunk_literal_allow_suppresses_and_is_counted() {
    let report = lint_src(
        "
fn f(pool: &Pool, buf: &mut [f32]) {
    // lint: allow(adhoc-chunk-literal) one-off probe buffer; boundaries feed no reduction
    pool.for_each_chunk_mut(buf, 512, |c, s| fill(c, s));
}
",
    );
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
    assert_eq!(report.suppressed.len(), 1);
    assert_eq!(report.suppressed[0].rule, RULE_ADHOC_CHUNK);
}

// ------------------------------------------------------------- panic-in-lib

#[test]
fn panic_in_lib_flags_unwrap_expect_panic() {
    let report = lint_src(
        "
pub fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}
pub fn g(x: Option<u32>) -> u32 {
    x.expect(\"present\")
}
pub fn h() {
    panic!(\"boom\");
}
",
    );
    assert_eq!(
        rules_of(&report),
        vec![(RULE_PANIC, 3), (RULE_PANIC, 6), (RULE_PANIC, 9)]
    );
}

#[test]
fn panic_in_lib_skips_tests_and_test_dirs() {
    let src = "
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        Some(1).unwrap();
        panic!(\"fine in tests\");
    }
}
";
    assert!(lint_src(src).live.is_empty());
    // The same panicking code in an integration-test file is out of
    // scope entirely (the rule only covers rust/src).
    let in_tests = lint_source("rust/tests/fixture.rs", "fn f() { None::<u32>.unwrap(); }");
    assert!(in_tests.live.is_empty());
}

#[test]
fn panic_in_lib_allow_suppresses_and_is_counted() {
    let report = lint_src(
        "
pub fn f(x: Option<u32>) -> u32 {
    // lint: allow(panic-in-lib) documented panicking variant; fallible twin is try_f
    x.unwrap()
}
",
    );
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
    assert_eq!(report.suppressed.len(), 1);
    assert_eq!(report.suppressed[0].rule, RULE_PANIC);
}

#[test]
fn allow_without_reason_does_not_suppress() {
    let report = lint_src(
        "
pub fn f(x: Option<u32>) -> u32 {
    // lint: allow(panic-in-lib)
    x.unwrap()
}
",
    );
    assert_eq!(rules_of(&report), vec![(RULE_PANIC, 4)]);
    assert!(report.suppressed.is_empty());
}

// ------------------------------------------------------------ lock-across-io

#[test]
fn lock_across_io_flags_guard_live_at_write() {
    let report = lint_src(
        "
fn send(m: &std::sync::Mutex<u32>, s: &mut std::net::TcpStream) {
    let guard = m.lock();
    s.write_all(b\"x\");
}
",
    );
    assert_eq!(rules_of(&report), vec![(RULE_LOCK_IO, 4)]);
    assert!(report.live[0].msg.contains("guard"));
}

#[test]
fn lock_across_io_clean_after_drop_or_scope() {
    let report = lint_src(
        "
fn send(m: &std::sync::Mutex<u32>, s: &mut std::net::TcpStream) {
    let guard = m.lock();
    drop(guard);
    s.write_all(b\"x\");
}
fn send2(m: &std::sync::Mutex<u32>, s: &mut std::net::TcpStream) {
    let mut len = 0u8;
    {
        let guard = m.lock();
        len = *guard as u8;
    }
    s.write_all(&[len]);
}
",
    );
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
}

#[test]
fn lock_across_io_allow_suppresses_and_is_counted() {
    let report = lint_src(
        "
fn send(m: &std::sync::Mutex<u32>, s: &mut std::net::TcpStream) {
    let guard = m.lock();
    // lint: allow(lock-across-io) single-threaded startup path; nothing else can contend
    s.write_all(b\"x\");
}
",
    );
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
    assert_eq!(report.suppressed.len(), 1);
    assert_eq!(report.suppressed[0].rule, RULE_LOCK_IO);
}

// ---------------------------------------------------------- wallclock-entropy

#[test]
fn wallclock_flags_instant_now_in_src() {
    let report = lint_src(
        "
fn seed() -> u64 {
    std::time::Instant::now().elapsed().as_nanos() as u64
}
",
    );
    assert_eq!(rules_of(&report), vec![(RULE_WALLCLOCK, 3)]);
}

#[test]
fn wallclock_clean_in_obs_and_tests() {
    let src = "
fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
";
    assert!(lint_source("rust/src/obs/mod.rs", src).live.is_empty());
    assert!(lint_source("rust/src/bench/mod.rs", src).live.is_empty());
    let in_test = "
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let _ = std::time::Instant::now();
    }
}
";
    assert!(lint_src(in_test).live.is_empty());
}

#[test]
fn wallclock_allow_suppresses_and_is_counted() {
    let report = lint_src(
        "
fn f() {
    // lint: allow(wallclock-entropy) realized latency metric only; never feeds seeds
    let _t0 = std::time::Instant::now();
}
",
    );
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
    assert_eq!(report.suppressed.len(), 1);
    assert_eq!(report.suppressed[0].rule, RULE_WALLCLOCK);
}

// ---------------------------------------------------------- wire-layout-drift

const WIRE_LABEL: &str = "rust/src/coordinator/wire.rs";

/// The real v3 layout values, mirrored from `coordinator/wire.rs`.
const WIRE_VALUES: [(&str, u64); 14] = [
    ("MAGIC", 0x6743_0003),
    ("TAG_HELLO", 1),
    ("TAG_SETUP", 2),
    ("TAG_TASK", 3),
    ("TAG_RESULT", 4),
    ("TAG_SHUTDOWN", 5),
    ("SCHEME_POLY", 0),
    ("SCHEME_RANDOM", 1),
    ("SCHEME_UNCODED", 2),
    ("SCHEME_APPROX", 3),
    ("SCHEME_HETERO", 4),
    ("FRAME_OVERHEAD", 9),
    ("RESULT_HEADER_BYTES", 13),
    ("MAX_PAYLOAD", 1 << 26),
];

fn wire_fixture_consts() -> String {
    // Express a few constants as the same expressions wire.rs uses, to
    // exercise the const-expression evaluator.
    String::from(
        "
pub const MAGIC: u32 = 0x6743_0003;
const TAG_HELLO: u8 = 1;
const TAG_SETUP: u8 = 2;
const TAG_TASK: u8 = 3;
const TAG_RESULT: u8 = 4;
const TAG_SHUTDOWN: u8 = 5;
pub const SCHEME_POLY: u8 = 0;
pub const SCHEME_RANDOM: u8 = 1;
pub const SCHEME_UNCODED: u8 = 2;
pub const SCHEME_APPROX: u8 = 3;
pub const SCHEME_HETERO: u8 = 4;
pub const FRAME_OVERHEAD: usize = 4 + 1 + 4;
pub const RESULT_HEADER_BYTES: usize = 4 + 8 + 1;
const MAX_PAYLOAD: usize = 1 << 26;
",
    )
}

fn expected_pin() -> u64 {
    let mut data = String::new();
    for (name, v) in WIRE_VALUES {
        data.push_str(name);
        data.push('=');
        data.push_str(&v.to_string());
        data.push(';');
    }
    fnv1a64(data.as_bytes())
}

#[test]
fn wire_drift_missing_fingerprint_is_flagged() {
    let report = lint_source(WIRE_LABEL, &wire_fixture_consts());
    assert_eq!(rules_of(&report), vec![(RULE_WIRE_DRIFT, 1)]);
    assert!(report.live[0].msg.contains("no WIRE_LAYOUT_FINGERPRINT"));
}

#[test]
fn wire_drift_clean_when_pin_matches() {
    let src = format!(
        "{}pub const WIRE_LAYOUT_FINGERPRINT: u64 = {:#x};\n",
        wire_fixture_consts(),
        expected_pin()
    );
    let report = lint_source(WIRE_LABEL, &src);
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
}

#[test]
fn wire_drift_layout_change_without_repin_is_flagged() {
    let src = format!(
        "{}pub const WIRE_LAYOUT_FINGERPRINT: u64 = {:#x};\n",
        wire_fixture_consts().replace("4 + 8 + 1", "4 + 8 + 2"),
        expected_pin()
    );
    let report = lint_source(WIRE_LABEL, &src);
    assert_eq!(rules_of(&report), vec![(RULE_WIRE_DRIFT, 1)]);
    assert!(report.live[0].msg.contains("bump MAGIC"), "msg: {}", report.live[0].msg);
}

#[test]
fn wire_drift_allow_suppresses_and_is_counted() {
    let src = format!(
        "// lint: allow(wire-layout-drift) mid-migration; the MAGIC bump lands with wire v4\n{}pub const WIRE_LAYOUT_FINGERPRINT: u64 = {:#x};\n",
        wire_fixture_consts().replace("4 + 8 + 1", "4 + 8 + 2"),
        expected_pin()
    );
    let report = lint_source(WIRE_LABEL, &src);
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
    assert_eq!(report.suppressed.len(), 1);
    assert_eq!(report.suppressed[0].rule, RULE_WIRE_DRIFT);
}

#[test]
fn wire_rule_only_runs_on_wire_rs() {
    // The same const block anywhere else is nobody's business.
    let report = lint_source("rust/src/coordinator/remote.rs", &wire_fixture_consts());
    assert!(report.live.is_empty(), "unexpected: {:?}", report.live);
}

// ----------------------------------------------------------------- lexer edges

#[test]
fn lexer_raw_strings_hide_their_contents() {
    let lexed = lex(r##"let s = r#"quote " and // not a comment and .unwrap( inside"#;"##);
    assert!(lexed.comments.is_empty());
    let strs: Vec<_> = lexed.toks.iter().filter(|t| t.kind == TokKind::Str).collect();
    assert_eq!(strs.len(), 1);
    assert!(!lexed.toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "unwrap"));
    // And the linter therefore sees nothing panicky.
    assert!(lint_src(r##"fn f() { let s = r#"call .unwrap( and panic!("no")"#; }"##)
        .live
        .is_empty());
}

#[test]
fn lexer_nested_block_comments() {
    let lexed = lex("/* outer /* inner */ still comment */ fn f() {}");
    assert_eq!(lexed.comments.len(), 1);
    assert_eq!(lexed.toks[0].text, "fn");
    assert!(lexed.comments[0].1.contains("inner"));
}

#[test]
fn lexer_lifetimes_vs_char_literals() {
    let lexed = lex("fn f<'a>(x: &'a u8) { let c = 'b'; let d = '\\n'; let s: &'static str = \"\"; }");
    let lifetimes: Vec<_> =
        lexed.toks.iter().filter(|t| t.kind == TokKind::Lifetime).map(|t| t.text.clone()).collect();
    let chars: Vec<_> =
        lexed.toks.iter().filter(|t| t.kind == TokKind::Char).map(|t| t.text.clone()).collect();
    assert_eq!(lifetimes, vec!["'a", "'a", "'static"]);
    assert_eq!(chars, vec!["'b'", "'\\n'"]);
}

#[test]
fn lexer_numeric_literals_stay_whole() {
    let lexed = lex("let x = 16_384usize; let y = 0x6743_0003u32; let z = 1.5e-3f64;");
    let nums: Vec<_> =
        lexed.toks.iter().filter(|t| t.kind == TokKind::Num).map(|t| t.text.clone()).collect();
    assert_eq!(nums, vec!["16_384usize", "0x6743_0003u32", "1.5e-3f64"]);
}

#[test]
fn lexer_positions_are_one_based_and_accurate() {
    let lexed = lex("fn f() {\n    x.unwrap()\n}\n");
    let unwrap = lexed
        .toks
        .iter()
        .find(|t| t.text == "unwrap")
        .map(|t| (t.line, t.col));
    assert_eq!(unwrap, Some((2, 7)));
}

// ------------------------------------------------------------------ self-lint

#[test]
fn repo_is_clean_against_committed_baseline() {
    // The acceptance invariant of the lint PR: `gradcode lint --deny`
    // passes on the repo itself, with the committed baseline (which
    // ships empty). cargo runs integration tests from the package
    // root, which is the repo root.
    let report = lint_tree(std::path::Path::new(".")).expect("lint_tree walks the repo");
    let baseline = match std::fs::read_to_string("lint.baseline") {
        Ok(text) => Baseline::parse(&text).expect("committed baseline parses"),
        Err(_) => Baseline::default(),
    };
    let (fresh, _grandfathered) = baseline.split(report.live);
    let rendered: Vec<String> = fresh.iter().map(|f| f.to_string()).collect();
    assert!(fresh.is_empty(), "new lint findings:\n{}", rendered.join("\n"));
}
