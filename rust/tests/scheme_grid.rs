//! Property-style grid test over every scheme and every feasible
//! `(n <= 8, s, m)` triple: exact decode must hold for **all** admissible
//! responder sets, not just the sampled ones the per-module unit tests
//! cover.
//!
//! Two layers of assertion:
//!
//! 1. **Coefficient-space exactness (f64, every admissible set).** With
//!    `BV[(t,u), w]` the coefficient of `g_t`'s `u`-component in `f_w`
//!    (the invariant every [`GradientCode`] documents for
//!    `matrix_b()·matrix_v()`), decode weights `W` are exact iff
//!    `Σ_i W[i,u] · BV[(t,u'), used_i] = δ_{u,u'}` for every subset `t` —
//!    the payload-free statement of "the decode reproduces the plain
//!    gradient sum". This runs over the *full* C(n, n-s) straggler
//!    enumeration.
//! 2. **f32 payload round trip (sampled sets).** The real encode →
//!    drop-stragglers → decode pipeline against the `sum_gradients`
//!    oracle, for a handful of responder sets per cell. Restricted to
//!    `m <= 3` like the seed's own property tests (larger `m` pushes the
//!    Vandermonde coefficients past 24-bit mantissas; the f64 layer
//!    above still covers those cells).
//!
//! Schemes: §III poly, §IV random, uncoded, and the heterogeneous group
//! scheme over three fleet profiles (uniform / linear / bimodal). For
//! hetero the grid additionally checks the *per-group minimal* responder
//! sets (smaller than `n - s` whenever a group has slack).

use std::sync::Arc;

use gradcode::coding::{
    sum_gradients, Decoder, Encoder, GradientCode, HeteroCode, PolynomialCode, RandomCode,
    SchemeConfig, UncodedScheme,
};
use gradcode::rngs::{Pcg64, Rng};
use gradcode::simulator::SpeedProfile;

/// All subsets of `{0..n}` with exactly `k` elements (ascending ids).
fn subsets_of_size(n: usize, k: usize) -> Vec<Vec<usize>> {
    (0u32..1 << n)
        .filter(|mask| mask.count_ones() as usize == k)
        .map(|mask| (0..n).filter(|&w| mask & (1 << w) != 0).collect())
        .collect()
}

/// Layer 1: coefficient-space exactness of `decode_weights(set)`.
fn assert_coefficient_exact(code: &dyn GradientCode, bv: &gradcode::linalg::Matrix, set: &[usize], ctx: &str) {
    let n = code.config().n;
    let m = code.config().m;
    let dw = code
        .decode_weights(set)
        .unwrap_or_else(|e| panic!("{ctx}: decode_weights({set:?}) failed: {e}"));
    let wmax = dw.weights.iter().fold(0.0f64, |a, &x| a.max(x.abs())).max(1.0);
    let tol = 1e-6 * wmax;
    for t in 0..n {
        for u in 0..m {
            for uprime in 0..m {
                let got: f64 = dw
                    .used
                    .iter()
                    .enumerate()
                    .map(|(i, &w)| dw.weight(i, u) * bv[(t * m + uprime, w)])
                    .sum();
                let want = if u == uprime { 1.0 } else { 0.0 };
                assert!(
                    (got - want).abs() < tol,
                    "{ctx}: set {set:?}, subset {t}, (u={u}, u'={uprime}): \
                     Σ W·BV = {got}, want {want} (tol {tol:.1e})"
                );
            }
        }
    }
}

/// Layer 2: full f32 pipeline against the plain gradient sum.
fn assert_payload_roundtrip(code: &dyn GradientCode, set: &[usize], seed: u64, ctx: &str) {
    let cfg = *code.config();
    let l = cfg.m * 2;
    let mut rng = Pcg64::seed_from_u64(seed);
    let grads: Vec<Vec<f32>> = (0..cfg.n)
        .map(|_| (0..l).map(|_| (rng.next_f64() * 2.0 - 1.0) as f32).collect())
        .collect();
    let mut transmitted = Vec::new();
    for w in 0..cfg.n {
        let enc = Encoder::new(code, w).unwrap();
        let views: Vec<&[f32]> = code
            .placement()
            .assigned(w)
            .iter()
            .map(|&t| grads[t].as_slice())
            .collect();
        transmitted.push(enc.encode(&views).unwrap());
    }
    let dec = Decoder::new(code, set)
        .unwrap_or_else(|e| panic!("{ctx}: Decoder::new({set:?}) failed: {e}"));
    let fs: Vec<&[f32]> =
        dec.used_workers().iter().map(|&w| transmitted[w].as_slice()).collect();
    let got = dec.decode(&fs).unwrap();
    let views: Vec<&[f32]> = grads.iter().map(|g| g.as_slice()).collect();
    let want = sum_gradients(&views);
    let scale = want.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-6);
    for v in 0..want.len() {
        assert!(
            (got[v] - want[v]).abs() / scale < 5e-3,
            "{ctx}: set {set:?} coord {v}: {} vs {}",
            got[v],
            want[v]
        );
    }
}

/// Run both layers for one scheme instance.
fn check_scheme(code: &dyn GradientCode, ctx: &str, payload_sets: usize, seed: u64) {
    let cfg = *code.config();
    let bv = code.matrix_b().matmul(&code.matrix_v());
    let all_sets = subsets_of_size(cfg.n, cfg.n - cfg.s);
    for set in &all_sets {
        assert_coefficient_exact(code, &bv, set, ctx);
    }
    // f32 payload layer on a deterministic sample of the sets.
    if cfg.m <= 3 {
        let stride = (all_sets.len() / payload_sets.max(1)).max(1);
        for (i, set) in all_sets.iter().step_by(stride).enumerate() {
            assert_payload_roundtrip(code, set, seed ^ (i as u64) << 8, ctx);
        }
    }
}

fn hetero_profiles() -> Vec<SpeedProfile> {
    vec![
        SpeedProfile::Uniform,
        SpeedProfile::Linear { ratio: 3.0 },
        SpeedProfile::Bimodal { slow_frac: 0.4, ratio: 4.0 },
    ]
}

/// Every feasible tight triple on n <= 8 workers.
fn feasible_triples(n_max: usize) -> Vec<(usize, usize, usize)> {
    let mut out = Vec::new();
    for n in 2..=n_max {
        for s in 0..n {
            for m in 1..=(n - s) {
                out.push((n, s, m));
            }
        }
    }
    out
}

#[test]
fn grid_poly_exact_on_every_admissible_set() {
    for (n, s, m) in feasible_triples(8) {
        let code = PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()).unwrap();
        check_scheme(&code, &format!("poly(n={n},s={s},m={m})"), 3, 0xA0 + n as u64);
    }
}

#[test]
fn grid_random_exact_on_every_admissible_set() {
    for (n, s, m) in feasible_triples(8) {
        let code = RandomCode::new(
            SchemeConfig::tight(n, s, m).unwrap(),
            0x5eed ^ (n * 100 + s * 10 + m) as u64,
        )
        .unwrap();
        check_scheme(&code, &format!("random(n={n},s={s},m={m})"), 2, 0xB0 + n as u64);
    }
}

#[test]
fn grid_uncoded_exact_with_full_attendance() {
    for n in 2..=8 {
        let code = UncodedScheme::new(n);
        check_scheme(&code, &format!("uncoded(n={n})"), 1, 0xC0 + n as u64);
    }
}

#[test]
fn grid_hetero_exact_on_every_admissible_set_and_profile() {
    for profile in hetero_profiles() {
        for (n, s, m) in feasible_triples(8) {
            let speeds = profile.speeds(n);
            let code = HeteroCode::from_speeds(n, s, m, &speeds)
                .unwrap_or_else(|e| panic!("hetero(n={n},s={s},m={m}): {e}"));
            let ctx = format!("hetero(n={n},s={s},m={m},{})", profile.label());
            check_scheme(&code, &ctx, 2, 0xD0 + n as u64);

            // Per-group minimal responder sets: the smallest sets the
            // coordinator's group rule can stop at. Check both the
            // "first need" and "last need" members of every group.
            let bv = code.matrix_b().matmul(&code.matrix_v());
            let quorums = code.group_quorums().unwrap();
            let firsts: Vec<usize> = quorums
                .iter()
                .flat_map(|(members, need)| members[..*need].to_vec())
                .collect();
            let lasts: Vec<usize> = quorums
                .iter()
                .flat_map(|(members, need)| members[members.len() - need..].to_vec())
                .collect();
            for mut set in [firsts, lasts] {
                set.sort_unstable();
                assert_coefficient_exact(&code, &bv, &set, &format!("{ctx} minimal"));
            }
        }
    }
}

#[test]
fn grid_sub_threshold_sets_are_rejected() {
    // One below the admissible size must fail cleanly for the exact
    // schemes (never silently return wrong weights).
    for (n, s, m) in feasible_triples(6) {
        if n - s <= 1 {
            continue;
        }
        let short: Vec<usize> = (0..n - s - 1).collect();
        let poly = PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()).unwrap();
        assert!(poly.decode_weights(&short).is_err(), "poly(n={n},s={s},m={m})");
        let speeds = SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 }.speeds(n);
        let hetero = HeteroCode::from_speeds(n, s, m, &speeds).unwrap();
        // Removing s+1 workers from one group must break that group.
        let groups = hetero.group_quorums().unwrap();
        let (members, need) = &groups[0];
        if members.len() >= *need && *need >= 1 {
            let survivors: Vec<usize> = (0..n)
                .filter(|w| !members[..members.len() - need + 1].contains(w))
                .collect();
            assert!(
                hetero.decode_weights(&survivors).is_err(),
                "hetero(n={n},s={s},m={m}): group stripped below quorum must fail"
            );
        }
    }
}

#[test]
fn grid_trait_objects_compose() {
    // The grid exercises every scheme through &dyn GradientCode — make
    // sure the Arc<dyn> path the trainer uses agrees on a spot check.
    let code: Arc<dyn GradientCode> = Arc::new(
        HeteroCode::from_speeds(
            6,
            1,
            1,
            &SpeedProfile::Bimodal { slow_frac: 0.5, ratio: 4.0 }.speeds(6),
        )
        .unwrap(),
    );
    let bv = code.matrix_b().matmul(&code.matrix_v());
    for set in subsets_of_size(6, 5) {
        assert_coefficient_exact(code.as_ref(), &bv, &set, "arc-hetero");
    }
}
