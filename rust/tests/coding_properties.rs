//! Property-based integration tests over the coding layer: the paper's
//! invariants swept across randomized `(n, d, s, m)` space with the
//! in-crate testkit harness.

use gradcode::coding::{
    is_achievable, reconstruction_error, verify_placement_bound, Decoder, Encoder,
    GradientCode, PolynomialCode, RandomCode, SchemeConfig,
};
use gradcode::rngs::{Pcg64, Rng};
use gradcode::testkit::{self, gen, CaseResult, Config};

/// Any tight triple with n <= 12 must decode exactly under every random
/// straggler pattern (Vandermonde is well-conditioned in this range).
#[test]
fn property_poly_roundtrip_over_random_triples() {
    testkit::check(
        Config { cases: 40, seed: 0xc0de01 },
        "poly-roundtrip",
        |rng| {
            // f32-payload regime: n <= 10 and m <= 3 keep the Vandermonde
            // coefficients small enough for 24-bit mantissas; the paper's
            // full n <= 20 stability claim is verified in f64 by
            // `stability::reconstruction_error_f64` (the paper's own
            // precision) in the stability bench and unit tests.
            let n = 2 + rng.next_index(9); // 2..=10
            let d = 1 + rng.next_index(n);
            let m = (1 + rng.next_index(d)).min(3);
            let s = d - m;
            let l = m * (1 + rng.next_index(8));
            let seed = rng.next_u64();
            (n, d, s, m, l, seed)
        },
        |&(n, _d, s, m, l, seed)| {
            let code = match PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()) {
                Ok(c) => c,
                Err(e) => return CaseResult::Fail(format!("construction: {e}")),
            };
            let err = reconstruction_error(&code, l, 3, seed);
            // f32 payload precision: large (d·m) combines accumulate a few
            // ulp per term; 5e-3 still catches any structural decode bug
            // (those produce O(1) errors).
            if err < 5e-3 {
                CaseResult::Pass
            } else {
                CaseResult::Fail(format!("rel err {err}"))
            }
        },
    );
}

/// Same sweep for the §IV random-matrix scheme (larger n allowed).
#[test]
fn property_random_scheme_roundtrip() {
    testkit::check(
        Config { cases: 30, seed: 0xc0de02 },
        "random-roundtrip",
        |rng| {
            let (n, d, s, m) = gen::scheme_triple(rng, 2, 20);
            let l = m * (1 + rng.next_index(8));
            let seed = rng.next_u64();
            (n, d, s, m, l, seed)
        },
        |&(n, _d, s, m, l, seed)| {
            let code = match RandomCode::new(SchemeConfig::tight(n, s, m).unwrap(), seed) {
                Ok(c) => c,
                Err(e) => return CaseResult::Fail(format!("construction: {e}")),
            };
            let err = reconstruction_error(&code, l, 3, seed ^ 1);
            if err < 1e-2 {
                CaseResult::Pass
            } else {
                CaseResult::Fail(format!("rel err {err}"))
            }
        },
    );
}

/// Claim 1 (converse): every generated placement covers each subset at
/// least s+m times; and sub-threshold triples are never achievable.
#[test]
fn property_bounds_consistency() {
    testkit::check_bool(
        Config { cases: 200, seed: 0xc0de03 },
        "bounds-consistency",
        |rng| gen::scheme_triple(rng, 2, 30),
        |&(n, d, s, m)| {
            let code = PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()).unwrap();
            is_achievable(n, n, d, s, m)
                && verify_placement_bound(code.placement(), s, m)
        },
    );
}

/// Encode linearity: f(αg + βh) = αf(g) + βf(h) — the structural property
/// Definition 1 condition 3 demands.
#[test]
fn property_encode_linearity() {
    testkit::check(
        Config { cases: 40, seed: 0xc0de04 },
        "encode-linearity",
        |rng| {
            let (n, _d, s, m) = gen::scheme_triple(rng, 2, 10);
            let l = m * (1 + rng.next_index(6));
            let w = rng.next_index(n);
            let a = rng.next_f64() as f32 * 2.0 - 1.0;
            let b = rng.next_f64() as f32 * 2.0 - 1.0;
            let seed = rng.next_u64();
            (n, s, m, l, w, a, b, seed)
        },
        |&(n, s, m, l, w, a, b, seed)| {
            let code = PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()).unwrap();
            let d = code.config().d;
            let enc = Encoder::new(&code, w).unwrap();
            let mut rng = Pcg64::seed_from_u64(seed);
            let g = gen::gradients(&mut rng, d, l);
            let h = gen::gradients(&mut rng, d, l);
            let combo: Vec<Vec<f32>> = (0..d)
                .map(|j| (0..l).map(|k| a * g[j][k] + b * h[j][k]).collect())
                .collect();
            let vg: Vec<&[f32]> = g.iter().map(|v| v.as_slice()).collect();
            let vh: Vec<&[f32]> = h.iter().map(|v| v.as_slice()).collect();
            let vc: Vec<&[f32]> = combo.iter().map(|v| v.as_slice()).collect();
            let fg = enc.encode(&vg).unwrap();
            let fh = enc.encode(&vh).unwrap();
            let fc = enc.encode(&vc).unwrap();
            for v in 0..fc.len() {
                let want = a * fg[v] + b * fh[v];
                if (fc[v] - want).abs() > 1e-3 {
                    return CaseResult::Fail(format!(
                        "v={v}: {} vs {want} (n={n},s={s},m={m})",
                        fc[v]
                    ));
                }
            }
            CaseResult::Pass
        },
    );
}

/// Decode is straggler-pattern independent: two disjoint responder sets
/// of size n-s yield the same reconstruction.
#[test]
fn property_decode_pattern_independent() {
    testkit::check(
        Config { cases: 30, seed: 0xc0de05 },
        "decode-pattern-independent",
        |rng| {
            let n = 4 + rng.next_index(8); // 4..=11
            let s = 1 + rng.next_index(2.min(n - 2)); // 1..=2
            let m = 1 + rng.next_index(3);
            if s + m > n {
                return (0, 0, 0, 0, 0); // discarded below
            }
            let l = m * (1 + rng.next_index(4));
            (n, s, m, l, rng.next_u64() as usize)
        },
        |&(n, s, m, l, seed)| {
            if n == 0 {
                return CaseResult::Discard;
            }
            let code = PolynomialCode::new(SchemeConfig::tight(n, s, m).unwrap()).unwrap();
            let mut rng = Pcg64::seed_from_u64(seed as u64);
            let grads = gen::gradients(&mut rng, n, l);
            let mut fs = Vec::new();
            for w in 0..n {
                let enc = Encoder::new(&code, w).unwrap();
                let views: Vec<&[f32]> = code
                    .placement()
                    .assigned(w)
                    .iter()
                    .map(|&t| grads[t].as_slice())
                    .collect();
                fs.push(enc.encode(&views).unwrap());
            }
            let decode_with = |stragglers: &[usize]| {
                let avail: Vec<usize> =
                    (0..n).filter(|w| !stragglers.contains(w)).collect();
                let dec = Decoder::new(&code, &avail).unwrap();
                let views: Vec<&[f32]> =
                    dec.used_workers().iter().map(|&w| fs[w].as_slice()).collect();
                dec.decode(&views).unwrap()
            };
            let st_a = Pcg64::seed_from_u64(seed as u64 ^ 7).sample_indices(n, s);
            let st_b = Pcg64::seed_from_u64(seed as u64 ^ 13).sample_indices(n, s);
            let ga = decode_with(&st_a);
            let gb = decode_with(&st_b);
            let scale = ga.iter().fold(0.0f32, |a, &x| a.max(x.abs())).max(1e-12);
            for k in 0..ga.len() {
                if (ga[k] - gb[k]).abs() / scale > 1e-2 {
                    return CaseResult::Fail(format!(
                        "coord {k}: {} vs {} (n={n},s={s},m={m},A={st_a:?},B={st_b:?})",
                        ga[k], gb[k]
                    ));
                }
            }
            CaseResult::Pass
        },
    );
}
