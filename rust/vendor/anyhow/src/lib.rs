//! Offline stand-in for the `anyhow` crate (API subset).
//!
//! The build environment ships no crates.io registry, so this vendored
//! micro-crate provides the pieces of anyhow 1.x the repository actually
//! uses: [`Error`] (a type-erased error with a context chain),
//! [`Result`], the [`Context`] extension trait, and the [`anyhow!`],
//! [`bail!`] and [`ensure!`] macros. Semantics match anyhow where it
//! matters here:
//!
//! - any `E: std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?` (and [`Error`] itself deliberately does *not*
//!   implement `std::error::Error`, which is what makes that blanket
//!   `From` coherent — the same trick anyhow uses);
//! - `Display` prints the outermost message; `Debug` prints the whole
//!   `Caused by:` chain, which is what `fn main() -> anyhow::Result<()>`
//!   shows on error exit.
//!
//! Not implemented (unused in this repository): downcasting, backtraces.

use std::fmt;

/// Type-erased error with a chain of context messages.
pub struct Error {
    msg: String,
    cause: Option<Box<Error>>,
}

impl Error {
    /// Error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { msg: message.to_string(), cause: None }
    }

    /// Error from a standard error value, preserving its source chain
    /// (as rendered text; this shim does not store the value itself).
    pub fn new<E>(error: E) -> Self
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        let mut messages = vec![error.to_string()];
        let mut source = error.source();
        while let Some(s) = source {
            messages.push(s.to_string());
            source = s.source();
        }
        let mut chain: Option<Box<Error>> = None;
        for msg in messages.into_iter().rev() {
            chain = Some(Box::new(Error { msg, cause: chain }));
        }
        *chain.expect("at least one message")
    }

    /// Wrap with an outer context message (outermost wins `Display`).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Error { msg: context.to_string(), cause: Some(Box::new(self)) }
    }

    /// The messages from outermost to innermost.
    pub fn chain(&self) -> Vec<&str> {
        let mut out = Vec::new();
        let mut cur = Some(self);
        while let Some(e) = cur {
            out.push(e.msg.as_str());
            cur = e.cause.as_deref();
        }
        out
    }

    /// The innermost message.
    pub fn root_cause(&self) -> &str {
        self.chain().last().copied().unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let chain = self.chain();
        if chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for msg in &chain[1..] {
                write!(f, "\n    {msg}")?;
            }
        }
        Ok(())
    }
}

// Coherent because `Error` does not implement `std::error::Error`.
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context()` / `.with_context()` to `Result`
/// and `Option`.
pub trait Context<T> {
    /// Attach a context message to the error case.
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;

    /// Attach a lazily-evaluated context message to the error case.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing thing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing thing"));
    }

    #[test]
    fn context_chains_and_display_is_outermost() {
        let e: Result<()> = Err(io_err()).context("opening config");
        let e = e.unwrap_err();
        assert_eq!(e.to_string(), "opening config");
        assert!(format!("{e:?}").contains("Caused by"));
        assert!(format!("{e:?}").contains("missing thing"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(e.to_string(), "nothing there");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("12"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
        let e = anyhow!("custom {}", 7);
        assert_eq!(e.to_string(), "custom 7");
    }
}
