"""AOT lowering tests: the HLO text artifacts exist, parse, and the
lowered module's numerics match the eager kernels (via jax's own
compile+run of the same StableHLO)."""

import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_worker_hlo_text_shape_and_content():
    text = aot.lower_worker(n=4, d=2, m=2, rows=4, dim=8)
    assert "HloModule" in text
    # entry computation consumes the four parameters
    assert text.count("parameter(") >= 4
    # output is a tuple of one f32[4] (dim/m = 4)
    assert "f32[4]" in text


def test_predict_hlo_text():
    text = aot.lower_predict(rows=16, dim=8)
    assert "HloModule" in text
    assert "f32[16]" in text


def test_artifact_names_roundtrip():
    assert (
        aot.worker_artifact_name(10, 3, 2, 64, 512)
        == "worker_n10_d3_m2_r64_l512.hlo.txt"
    )
    assert aot.predict_artifact_name(256, 512) == "predict_r256_l512.hlo.txt"


def test_lowered_worker_matches_eager(tmp_path):
    """Compile the lowered module with jax and compare against eager —
    catches lowering bugs before the rust side ever sees the artifact."""
    n, d, m, rows, dim = 4, 2, 2, 4, 8
    xs = jax.random.normal(jax.random.PRNGKey(0), (d, rows, dim), dtype=jnp.float32)
    ys = (jax.random.uniform(jax.random.PRNGKey(1), (d, rows)) < 0.5).astype(
        jnp.float32
    )
    beta = jax.random.normal(jax.random.PRNGKey(2), (dim,), dtype=jnp.float32)
    coeffs = jax.random.normal(jax.random.PRNGKey(3), (d, m), dtype=jnp.float32)

    def fn(xs, ys, beta, coeffs):
        return (model.worker_step(xs, ys, beta, coeffs),)

    compiled = jax.jit(fn).lower(xs, ys, beta, coeffs).compile()
    got = compiled(xs, ys, beta, coeffs)[0]
    want = model.worker_step(xs, ys, beta, coeffs)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_main_writes_artifacts_and_manifest(tmp_path, monkeypatch):
    out = tmp_path / "artifacts"
    import sys

    monkeypatch.setattr(
        sys,
        "argv",
        [
            "aot",
            "--out-dir",
            str(out),
            "--n",
            "4",
            "--s",
            "1",
            "--m",
            "1",
            "--rows",
            "4",
            "--dim",
            "8",
            "--eval-rows",
            "8",
        ],
    )
    aot.main()
    files = sorted(os.listdir(out))
    assert "manifest.txt" in files
    assert any(f.startswith("worker_n4_d2_m1") for f in files)
    assert any(f.startswith("predict_r8") for f in files)
    manifest = (out / "manifest.txt").read_text().strip().splitlines()
    assert len(manifest) == 2
    kinds = {ln.split()[1] for ln in manifest}
    assert kinds == {"worker", "predict"}
