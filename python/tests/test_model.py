"""L2 model correctness: worker_step vs the fused reference, and the
coding-level invariant that encoded vectors decode to the true sum
gradient (a python mirror of the rust round-trip tests, over the same
math the AOT artifacts freeze)."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import predict_ref, worker_step_ref

jax.config.update("jax_platform_name", "cpu")


def _data(seed, d, rows, dim, m):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xs = jax.random.normal(k1, (d, rows, dim), dtype=jnp.float32)
    ys = (jax.random.uniform(k2, (d, rows)) < 0.5).astype(jnp.float32)
    beta = jax.random.normal(k3, (dim,), dtype=jnp.float32) * 0.1
    coeffs = jax.random.normal(k4, (d, m), dtype=jnp.float32)
    return xs, ys, beta, coeffs


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(min_value=1, max_value=5),
    m=st.integers(min_value=1, max_value=4),
    rows=st.integers(min_value=2, max_value=24),
    lv=st.integers(min_value=2, max_value=24),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_worker_step_matches_ref(d, m, rows, lv, seed):
    dim = lv * m
    xs, ys, beta, coeffs = _data(seed, d, rows, dim, m)
    got = model.worker_step(xs, ys, beta, coeffs)
    want = worker_step_ref(xs, ys, beta, coeffs)
    assert got.shape == (dim // m,)
    np.testing.assert_allclose(got, want, rtol=5e-5, atol=5e-5)


def test_predict_matches_ref():
    key = jax.random.PRNGKey(7)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (32, 16), dtype=jnp.float32)
    beta = jax.random.normal(k2, (16,), dtype=jnp.float32)
    np.testing.assert_allclose(
        model.predict(x, beta), predict_ref(x, beta), rtol=1e-6, atol=1e-6
    )


def test_full_coded_roundtrip_decodes_sum_gradient():
    """Python mirror of the paper's end-to-end identity: encode at every
    worker with the Vandermonde/poly coefficients, decode from any n-s
    responders, recover the full-data sum gradient.

    Coefficients and decode weights are computed here from first
    principles (Vandermonde algebra), independently of the rust
    implementation — a cross-language consistency check.
    """
    n, d, s, m = 5, 3, 1, 2
    rows, dim = 8, 12
    thetas = np.array([-2.0, -1.0, 0.0, 1.0, 2.0])

    # Build B exactly as §III (numpy mirror of rust coding::poly).
    cols = n - s

    def poly_from_roots(roots):
        c = np.array([1.0])
        for r in roots:
            c = np.convolve(c, [-r, 1.0])
        return c  # ascending

    b = np.zeros((m * n, cols))
    for t in range(n):
        roots = [thetas[(t + j) % n] for j in range(1, n - d + 1)]
        p1 = poly_from_roots(roots)
        pu = p1.copy()
        for u in range(m):
            if u > 0:
                lam = pu[n - d - 1]
                shifted = np.concatenate([[0.0], pu])
                pu = shifted - lam * np.concatenate([p1, [0.0] * (len(shifted) - len(p1))])
            b[t * m + u, : len(pu)] = pu[:cols]

    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, n + 1)
    subsets_x = [
        jax.random.normal(ks[t], (rows, dim), dtype=jnp.float32) for t in range(n)
    ]
    subsets_y = [
        (jax.random.uniform(ks[t], (rows,)) < 0.5).astype(jnp.float32)
        for t in range(n)
    ]
    beta = jax.random.normal(ks[n], (dim,), dtype=jnp.float32) * 0.1

    # Every worker transmits via the L2 graph.
    fs = []
    for w in range(n):
        assigned = [(w + j) % n for j in range(d)]
        xs = jnp.stack([subsets_x[t] for t in assigned])
        ys = jnp.stack([subsets_y[t] for t in assigned])
        powers = np.array([thetas[w] ** r for r in range(cols)])
        coeffs = np.array(
            [[b[t * m + u] @ powers for u in range(m)] for t in assigned],
            dtype=np.float32,
        )
        fs.append(np.asarray(model.worker_step(xs, ys, beta, jnp.asarray(coeffs))))

    # True sum gradient.
    from compile.kernels.ref import logistic_grad_ref

    want = np.sum(
        [np.asarray(logistic_grad_ref(subsets_x[t], subsets_y[t], beta)) for t in range(n)],
        axis=0,
    )

    # Decode from every single-straggler pattern.
    for straggler in range(n):
        avail = [w for w in range(n) if w != straggler]
        a = np.vstack([[thetas[w] ** r for w in avail] for r in range(cols)])
        inv = np.linalg.inv(a)
        got = np.zeros(dim, dtype=np.float64)
        for u in range(m):
            wvec = inv[:, n - d + u]
            comb = np.sum([wvec[i] * fs[w] for i, w in enumerate(avail)], axis=0)
            got[u::m] = comb
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
