"""L1 kernel correctness: Pallas (interpret=True) vs pure-jnp oracle.

Hypothesis sweeps shapes; every case asserts allclose against ref.py.
This is the core correctness signal for the compute layer — the same
kernels are what the AOT artifacts execute on the rust request path.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import encode, logistic_grad
from compile.kernels.encode import pick_block_v
from compile.kernels.logistic_grad import pick_block_rows
from compile.kernels.ref import (
    encode_ref,
    logistic_grad_ref,
    logistic_loss_ref,
)

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape):
    return jax.random.normal(key, shape, dtype=jnp.float32)


class TestLogisticGrad:
    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.integers(min_value=1, max_value=96),
        dim=st.integers(min_value=1, max_value=160),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, rows, dim, seed):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = rand(k1, rows, dim)
        y = (jax.random.uniform(k2, (rows,)) < 0.5).astype(jnp.float32)
        beta = rand(k3, dim) * 0.1
        got = logistic_grad(x, y, beta)
        want = logistic_grad_ref(x, y, beta)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        block=st.integers(min_value=1, max_value=64),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_block_size_invariance(self, block, seed):
        rows, dim = 64, 48
        if rows % block != 0:
            block = pick_block_rows(rows, block)
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        x = rand(k1, rows, dim)
        y = (jax.random.uniform(k2, (rows,)) < 0.5).astype(jnp.float32)
        beta = rand(k3, dim) * 0.1
        got = logistic_grad(x, y, beta, block_rows=block)
        want = logistic_grad_ref(x, y, beta)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    def test_matches_jax_grad_of_loss(self):
        # kernel == R * grad(mean NLL): the strongest oracle available.
        key = jax.random.PRNGKey(0)
        k1, k2, k3 = jax.random.split(key, 3)
        rows, dim = 32, 20
        x = rand(k1, rows, dim)
        y = (jax.random.uniform(k2, (rows,)) < 0.5).astype(jnp.float32)
        beta = rand(k3, dim) * 0.2
        got = logistic_grad(x, y, beta)
        want = rows * jax.grad(lambda b: logistic_loss_ref(x, y, b))(beta)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_beta_gives_half_residuals(self):
        x = jnp.eye(4, dtype=jnp.float32)
        y = jnp.array([1.0, 0.0, 1.0, 0.0], dtype=jnp.float32)
        got = logistic_grad(x, y, jnp.zeros(4, dtype=jnp.float32))
        np.testing.assert_allclose(got, [-0.5, 0.5, -0.5, 0.5], atol=1e-6)

    def test_dtype_is_f32(self):
        x = jnp.ones((8, 4), dtype=jnp.float32)
        y = jnp.zeros(8, dtype=jnp.float32)
        out = logistic_grad(x, y, jnp.zeros(4, dtype=jnp.float32))
        assert out.dtype == jnp.float32
        assert out.shape == (4,)


class TestEncode:
    @settings(max_examples=25, deadline=None)
    @given(
        d=st.integers(min_value=1, max_value=8),
        m=st.integers(min_value=1, max_value=6),
        lv=st.integers(min_value=1, max_value=64),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, d, m, lv, seed):
        l = lv * m
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        g = rand(k1, d, l)
        c = rand(k2, d, m)
        got = encode(g, c)
        want = encode_ref(g, c)
        assert got.shape == (lv,)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)

    @settings(max_examples=10, deadline=None)
    @given(
        block=st.integers(min_value=1, max_value=128),
        seed=st.integers(min_value=0, max_value=2**31 - 1),
    )
    def test_block_size_invariance(self, block, seed):
        d, m, lv = 3, 2, 48
        block = pick_block_v(lv, block)
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        g = rand(k1, d, lv * m)
        c = rand(k2, d, m)
        got = encode(g, c, block_v=block)
        np.testing.assert_allclose(got, encode_ref(g, c), rtol=2e-5, atol=2e-5)

    def test_m1_is_weighted_sum(self):
        # m=1 degenerates to a plain weighted sum of gradients.
        g = jnp.array([[1.0, 2.0, 3.0], [10.0, 20.0, 30.0]], dtype=jnp.float32)
        c = jnp.array([[2.0], [0.5]], dtype=jnp.float32)
        got = encode(g, c)
        np.testing.assert_allclose(got, [7.0, 14.0, 21.0], atol=1e-6)

    def test_identity_coeff_extracts_strided_components(self):
        # d=1, c = e_u picks every m-th coordinate starting at u.
        l, m = 12, 3
        g = jnp.arange(l, dtype=jnp.float32)[None, :]
        for u in range(m):
            c = jnp.zeros((1, m), dtype=jnp.float32).at[0, u].set(1.0)
            got = encode(g, c)
            np.testing.assert_allclose(got, np.arange(l)[u::m], atol=1e-6)

    def test_rejects_indivisible_dim(self):
        g = jnp.ones((2, 7), dtype=jnp.float32)
        c = jnp.ones((2, 2), dtype=jnp.float32)
        with pytest.raises(AssertionError):
            encode(g, c)
