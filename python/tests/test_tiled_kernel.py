"""Paper-scale tiled logistic-gradient kernel vs the oracle and vs the
single-pass kernel — including shapes where the full-width kernel's
block would not fit VMEM on real hardware."""

import numpy as np
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels.logistic_grad import logistic_grad
from compile.kernels.logistic_grad_tiled import (
    logistic_grad_tiled,
    pick_block_cols,
)
from compile.kernels.ref import logistic_grad_ref

jax.config.update("jax_platform_name", "cpu")


def _data(seed, rows, dim):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(k1, (rows, dim), dtype=jnp.float32)
    y = (jax.random.uniform(k2, (rows,)) < 0.5).astype(jnp.float32)
    beta = jax.random.normal(k3, (dim,), dtype=jnp.float32) * 0.1
    return x, y, beta


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=64),
    dim=st.integers(min_value=1, max_value=200),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tiled_matches_ref(rows, dim, seed):
    x, y, beta = _data(seed, rows, dim)
    got = logistic_grad_tiled(x, y, beta)
    want = logistic_grad_ref(x, y, beta)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(
    bc=st.integers(min_value=1, max_value=96),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_tiled_block_cols_invariance(bc, seed):
    rows, dim = 32, 96
    bc = pick_block_cols(dim, bc)
    x, y, beta = _data(seed, rows, dim)
    got = logistic_grad_tiled(x, y, beta, block_cols=bc)
    want = logistic_grad_ref(x, y, beta)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_tiled_matches_fullwidth_kernel():
    x, y, beta = _data(3, 48, 120)
    a = logistic_grad_tiled(x, y, beta, block_rows=16, block_cols=40)
    b = logistic_grad(x, y, beta, block_rows=16)
    np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)


def test_paper_scale_column_count():
    """A wide (VMEM-hostile for the full-width kernel) shape: l = 21467
    (odd, prime-ish) with small blocks — exercises non-power-of-2 tiling.
    """
    rows, dim = 8, 21467  # prime dim -> block_cols falls back to 1? no:
    # pick_block_cols finds the largest divisor <= 256; for a prime this
    # is 1, which still works (just slow) — use a composite close to it.
    dim = 21450  # 2·3·5²·11·13
    x, y, beta = _data(5, rows, dim)
    got = logistic_grad_tiled(x, y, beta, block_rows=8, block_cols=195)
    want = logistic_grad_ref(x, y, beta)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
