"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
bundled XLA (xla_extension 0.5.1) rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Artifacts (shapes are baked at lowering time):

- ``worker_n{n}_d{d}_m{m}_r{rows}_l{dim}.hlo.txt``
    worker_step: (xs f32[d,rows,dim], ys f32[d,rows], beta f32[dim],
                  coeffs f32[d,m]) -> (f f32[dim/m],)
- ``predict_r{rows}_l{dim}.hlo.txt``
    predict: (x f32[rows,dim], beta f32[dim]) -> (probs f32[rows],)

plus ``manifest.txt`` with one line per artifact:
``name kind n d m rows dim``.

Usage (from python/):
  python -m compile.aot --out-dir ../artifacts --n 10 --s 1 --m 2 \
      --rows 64 --dim 512 --eval-rows 256
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_worker(n: int, d: int, m: int, rows: int, dim: int) -> str:
    assert dim % m == 0, f"m={m} must divide dim={dim}"
    xs = jax.ShapeDtypeStruct((d, rows, dim), jnp.float32)
    ys = jax.ShapeDtypeStruct((d, rows), jnp.float32)
    beta = jax.ShapeDtypeStruct((dim,), jnp.float32)
    coeffs = jax.ShapeDtypeStruct((d, m), jnp.float32)

    def fn(xs, ys, beta, coeffs):
        return (model.worker_step(xs, ys, beta, coeffs),)

    return to_hlo_text(jax.jit(fn).lower(xs, ys, beta, coeffs))


def lower_predict(rows: int, dim: int) -> str:
    x = jax.ShapeDtypeStruct((rows, dim), jnp.float32)
    beta = jax.ShapeDtypeStruct((dim,), jnp.float32)

    def fn(x, beta):
        return (model.predict(x, beta),)

    return to_hlo_text(jax.jit(fn).lower(x, beta))


def worker_artifact_name(n: int, d: int, m: int, rows: int, dim: int) -> str:
    return f"worker_n{n}_d{d}_m{m}_r{rows}_l{dim}.hlo.txt"


def predict_artifact_name(rows: int, dim: int) -> str:
    return f"predict_r{rows}_l{dim}.hlo.txt"


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--n", type=int, default=10, help="workers (= subsets)")
    ap.add_argument("--s", type=int, default=1, help="straggler tolerance")
    ap.add_argument("--m", type=int, default=2, help="communication reduction")
    ap.add_argument("--d", type=int, default=0, help="load (default s+m)")
    ap.add_argument("--rows", type=int, default=64, help="rows per subset")
    ap.add_argument("--dim", type=int, default=512, help="gradient dim l")
    ap.add_argument("--eval-rows", type=int, default=256)
    ap.add_argument("--skip-predict", action="store_true")
    args = ap.parse_args()

    d = args.d or (args.s + args.m)
    assert d >= args.s + args.m, "Theorem 1: need d >= s + m"
    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.txt")
    entries = []
    if os.path.exists(manifest_path):
        with open(manifest_path) as fh:
            entries = [ln.strip() for ln in fh if ln.strip()]

    def record(line: str) -> None:
        if line not in entries:
            entries.append(line)

    name = worker_artifact_name(args.n, d, args.m, args.rows, args.dim)
    text = lower_worker(args.n, d, args.m, args.rows, args.dim)
    with open(os.path.join(args.out_dir, name), "w") as fh:
        fh.write(text)
    record(f"{name} worker {args.n} {d} {args.m} {args.rows} {args.dim}")
    print(f"wrote {name} ({len(text)} chars)")

    if not args.skip_predict:
        pname = predict_artifact_name(args.eval_rows, args.dim)
        ptext = lower_predict(args.eval_rows, args.dim)
        with open(os.path.join(args.out_dir, pname), "w") as fh:
            fh.write(ptext)
        record(f"{pname} predict 0 0 0 {args.eval_rows} {args.dim}")
        print(f"wrote {pname} ({len(ptext)} chars)")

    with open(manifest_path, "w") as fh:
        fh.write("\n".join(entries) + "\n")
    print(f"manifest: {len(entries)} artifacts")


if __name__ == "__main__":
    main()
