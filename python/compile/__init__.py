"""Build-time compile path (L1 kernels + L2 model + AOT lowering).

Never imported at runtime: ``make artifacts`` runs ``compile.aot`` once,
and the rust binary executes the emitted HLO through PJRT from then on.
"""
