"""L2 JAX model: the per-worker computation graph of the coded scheme.

``worker_step`` is what every worker executes each iteration — ``d``
Pallas partial-gradient kernels followed by the Pallas coded-combine
kernel — fused into one jitted function so the whole thing lowers into a
single HLO module for the rust runtime (see ``aot.py``).

The loop over the ``d`` subsets is unrolled statically: ``d <= n <= 30``
in every paper configuration, and unrolling keeps each pallas_call's
shapes static, which both the interpret-mode executor and the AOT
lowering require.

``predict`` (master-side evaluation) is plain jnp — it is not a hot spot.
"""

import jax
import jax.numpy as jnp

from .kernels import encode, logistic_grad
from .kernels.ref import logistic_loss_ref


def worker_step(xs, ys, beta, coeffs):
    """One worker's transmitted vector.

    Args:
      xs: f32[d, R, L] the worker's assigned subsets.
      ys: f32[d, R] labels.
      beta: f32[L] current parameters (broadcast from the master).
      coeffs: f32[d, m] encode coefficients (B·V_w restricted, see
        ``coding::GradientCode::encode_coeffs`` on the rust side).

    Returns:
      f32[L/m] coded vector f_w.
    """
    d = xs.shape[0]
    grads = jnp.stack(
        [logistic_grad(xs[j], ys[j], beta) for j in range(d)], axis=0
    )
    return encode(grads, coeffs)


def predict(x, beta):
    """sigmoid(X beta) over an evaluation block."""
    return jax.nn.sigmoid(
        jnp.dot(x, beta, preferred_element_type=jnp.float32)
    )


def loss(x, y, beta):
    """Mean NLL (diagnostics; gradient checks use jax.grad of this)."""
    return logistic_loss_ref(x, y, beta)
