"""L1 Pallas kernel: the coded combine (Eq. 18/25).

Given the worker's stacked partial gradients ``G (d, L)`` — viewed as
``(d, L/m, m)`` — and its coefficient block ``C (d, m)``, produce the
transmitted vector ``f[v] = sum_{j,u} C[j,u] * G[j, v, u]``.

TPU mapping: the grid tiles the output index ``v``; each step streams a
``(d, BV, m)`` gradient block through VMEM and contracts the tiny
``(d, m)`` coefficient block (which BlockSpec keeps resident across all
steps). ``d*m`` is at most a few hundred, so the contraction is
VPU-bound — the point of the kernel is the single streaming pass over
the gradient (the dominant HBM traffic), not FLOPs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, c_ref, o_ref):
    # g: (d, BV, m), c: (d, m) -> o: (BV,)
    o_ref[...] = jnp.einsum(
        "jvu,ju->v", g_ref[...], c_ref[...], preferred_element_type=jnp.float32
    )


def pick_block_v(lv: int, target: int = 512) -> int:
    """Largest divisor of ``lv`` that is <= target."""
    bv = min(lv, target)
    while lv % bv != 0:
        bv -= 1
    return bv


@functools.partial(jax.jit, static_argnames=("block_v",))
def encode(g, c, *, block_v=None):
    """Pallas-backed coded combine. g f32[d, L], c f32[d, m] -> f32[L/m]."""
    d, l = g.shape
    m = c.shape[1]
    assert l % m == 0, f"m={m} must divide L={l}"
    lv = l // m
    bv = block_v or pick_block_v(lv)
    gr = g.reshape(d, lv, m)
    return pl.pallas_call(
        _kernel,
        grid=(lv // bv,),
        in_specs=[
            pl.BlockSpec((d, bv, m), lambda i: (0, i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bv,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((lv,), jnp.float32),
        interpret=True,
    )(gr, c)
