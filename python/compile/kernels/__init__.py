"""L1 Pallas kernels + pure-jnp reference oracles."""

from .encode import encode
from .logistic_grad import logistic_grad
from .logistic_grad_tiled import logistic_grad_tiled

__all__ = ["encode", "logistic_grad", "logistic_grad_tiled"]
