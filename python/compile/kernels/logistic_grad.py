"""L1 Pallas kernel: fused logistic partial gradient.

Computes ``g = X^T (sigmoid(X @ beta) - y)`` for one data subset in a
single pass over ``X``: the residual never round-trips to HBM.

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles rows of
``X``; each step loads an ``(BR, L)`` block into VMEM, runs two MXU
matmuls (``X_blk @ beta`` forward, ``r @ X_blk`` transpose-accumulate)
and accumulates into the output block, which BlockSpec pins to the same
VMEM tile across all grid steps (classic revisiting-output reduction).
The paper targets CPU clusters so there is no CUDA idiom to port; the
insight carried over is fusing the elementwise sigmoid between the two
matmuls so arithmetic intensity stays MXU-bound.

Lowered with ``interpret=True`` everywhere in this repo: the CPU PJRT
plugin cannot execute Mosaic custom-calls (see /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, y_ref, b_ref, o_ref):
    """One row-block step: o += X_blk^T (sigmoid(X_blk @ beta) - y_blk)."""
    x = x_ref[...]  # (BR, L)
    z = jnp.dot(x, b_ref[...], preferred_element_type=jnp.float32)  # (BR,)
    r = jax.nn.sigmoid(z) - y_ref[...]
    contrib = jnp.dot(r, x, preferred_element_type=jnp.float32)  # (L,)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = contrib

    @pl.when(pl.program_id(0) > 0)
    def _acc():
        o_ref[...] += contrib


def pick_block_rows(rows: int, target: int = 128) -> int:
    """Largest divisor of ``rows`` that is <= target (VMEM-friendly)."""
    br = min(rows, target)
    while rows % br != 0:
        br -= 1
    return br


@functools.partial(jax.jit, static_argnames=("block_rows",))
def logistic_grad(x, y, beta, *, block_rows=None):
    """Pallas-backed partial gradient. Shapes: x f32[R,L], y f32[R],
    beta f32[L] -> f32[L]."""
    rows, dim = x.shape
    br = block_rows or pick_block_rows(rows)
    grid = (rows // br,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, dim), lambda i: (i, 0)),
            pl.BlockSpec((br,), lambda i: (i,)),
            pl.BlockSpec((dim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((dim,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((dim,), jnp.float32),
        interpret=True,
    )(x, y, beta)
