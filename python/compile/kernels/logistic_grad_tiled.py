"""L1 Pallas kernel, paper-scale variant: 2-D-tiled logistic gradient.

The fused single-kernel ``logistic_grad`` streams full-width ``(BR, L)``
blocks; at the paper's l = 343,474 a 64-row block is ~88 MB — far over a
TPU core's ~16 MB VMEM. This variant tiles BOTH dimensions with a
two-phase schedule, keeping every block at ``(BR, BC)``:

  phase 1 (``_forward_kernel``): grid (row_blocks, col_blocks) —
      accumulate ``z[rb] += X[rb, cb] @ beta[cb]`` over column blocks
      (output revisits the same ``(BR,)`` VMEM tile across the cb axis);
      then the tiny elementwise ``r = sigmoid(z) - y`` in plain jnp.
  phase 2 (``_backward_kernel``): grid (col_blocks, row_blocks) —
      accumulate ``g[cb] += r[rb] @ X[rb, cb]`` over row blocks.

X is streamed from HBM exactly twice (the minimum for this dataflow
without keeping all residuals' inputs resident), each matmul feeds the
MXU with a ``(BR, BC)`` tile, and VMEM usage is
``BR·BC·4 + O(BR + BC)`` bytes, independent of l.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .logistic_grad import pick_block_rows


def _forward_kernel(x_ref, b_ref, z_ref):
    # z[rb] += X[rb, cb] @ beta[cb]; cb is the minor grid axis.
    partial = jnp.dot(x_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        z_ref[...] = partial

    @pl.when(pl.program_id(1) > 0)
    def _acc():
        z_ref[...] += partial


def _backward_kernel(x_ref, r_ref, g_ref):
    # g[cb] += r[rb] @ X[rb, cb]; rb is the minor grid axis.
    partial = jnp.dot(r_ref[...], x_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        g_ref[...] = partial

    @pl.when(pl.program_id(1) > 0)
    def _acc():
        g_ref[...] += partial


def pick_block_cols(dim: int, target: int = 256) -> int:
    """Largest divisor of ``dim`` that is <= target."""
    bc = min(dim, target)
    while dim % bc != 0:
        bc -= 1
    return bc


@functools.partial(jax.jit, static_argnames=("block_rows", "block_cols"))
def logistic_grad_tiled(x, y, beta, *, block_rows=None, block_cols=None):
    """Column-tiled logistic partial gradient.

    Same contract as ``logistic_grad`` (x f32[R,L], y f32[R], beta
    f32[L] -> f32[L]) but with bounded VMEM at any L.
    """
    rows, dim = x.shape
    br = block_rows or pick_block_rows(rows)
    bc = block_cols or pick_block_cols(dim)
    rb, cb = rows // br, dim // bc

    # Phase 1: forward logits, accumulated over column blocks.
    z = pl.pallas_call(
        _forward_kernel,
        grid=(rb, cb),
        in_specs=[
            pl.BlockSpec((br, bc), lambda i, j: (i, j)),
            pl.BlockSpec((bc,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((rows,), jnp.float32),
        interpret=True,
    )(x, beta)
    r = jax.nn.sigmoid(z) - y

    # Phase 2: transpose-accumulate, column blocks as the major axis.
    return pl.pallas_call(
        _backward_kernel,
        grid=(cb, rb),
        in_specs=[
            pl.BlockSpec((br, bc), lambda j, i: (i, j)),
            pl.BlockSpec((br,), lambda j, i: (i,)),
        ],
        out_specs=pl.BlockSpec((bc,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((dim,), jnp.float32),
        interpret=True,
    )(x, r)
