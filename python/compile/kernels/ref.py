"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are tested against (pytest +
hypothesis in ``python/tests``), and double as readable specifications:

- ``logistic_grad_ref``: the paper's workload hot spot, the partial
  gradient of the logistic loss over one data subset,
  ``g = X^T (sigmoid(X @ beta) - y)``.
- ``encode_ref``: the coded combine of Eq. 18/25 — given the worker's
  ``d`` partial gradients (rows of ``G``) and its dense coefficient block
  ``C[j, u] = c_{j*m+u}``, produce the transmitted vector
  ``f[v] = sum_{j,u} C[j, u] * G[j, v*m + u]``.
- ``worker_step_ref``: both stages fused — what one worker transmits.
"""

import jax
import jax.numpy as jnp


def logistic_grad_ref(x, y, beta):
    """Partial gradient of one subset: X^T (sigmoid(X beta) - y).

    Args:
      x: f32[R, L] design block.
      y: f32[R] labels in {0, 1}.
      beta: f32[L] parameters.

    Returns:
      f32[L] sum gradient over the block.
    """
    r = jax.nn.sigmoid(x @ beta) - y
    return r @ x


def logistic_loss_ref(x, y, beta):
    """Mean negative log-likelihood (the loss whose gradient we compute).

    ``jax.grad`` of this (times R) must equal ``logistic_grad_ref`` — that
    identity is one of the kernel tests.
    """
    logits = x @ beta
    # log(1 + e^z) - y z, numerically stabilized
    return jnp.mean(jnp.logaddexp(0.0, logits) - y * logits)


def encode_ref(g, c):
    """Coded combine: f[v] = sum_{j,u} c[j,u] g[j, v*m+u].

    Args:
      g: f32[d, L] stacked partial gradients (m | L).
      c: f32[d, m] per-(subset, component-shift) coefficients.

    Returns:
      f32[L/m] transmitted vector.
    """
    d, l = g.shape
    m = c.shape[1]
    gr = g.reshape(d, l // m, m)
    return jnp.einsum("jvu,ju->v", gr, c)


def worker_step_ref(xs, ys, beta, c):
    """One worker's full step: d partial gradients + coded combine.

    Args:
      xs: f32[d, R, L] the worker's d assigned data subsets.
      ys: f32[d, R] labels.
      beta: f32[L].
      c: f32[d, m] encode coefficients.

    Returns:
      f32[L/m] the transmitted vector f_w.
    """
    grads = jax.vmap(logistic_grad_ref, in_axes=(0, 0, None))(xs, ys, beta)
    return encode_ref(grads, c)


def predict_ref(x, beta):
    """sigmoid(X beta) — master-side evaluation probabilities."""
    return jax.nn.sigmoid(x @ beta)
