//! E1 — the paper's Fig. 2 / Table II worked example.
//!
//! n = k = 5, d = 3, θ = (-2, -1, 0, 1, 2), l = 2, for both operating
//! points of the tradeoff:
//!   (a) s = 2, m = 1 — transmit 2 scalars, decode from any 3 workers;
//!   (b) s = 1, m = 2 — transmit 1 scalar, decode from any 4 workers.
//! For (b) it prints the per-straggler decode table (our Table II): the
//! unique linear combinations of the returned scalars reconstructing each
//! coordinate of the sum gradient.
//!
//!     cargo run --release --example fig2_table2

use gradcode::coding::{
    integer_thetas, Decoder, Encoder, GradientCode, PolynomialCode, SchemeConfig,
};

fn run_point(s: usize, m: usize) -> anyhow::Result<()> {
    let cfg = SchemeConfig::tight(5, s, m)?;
    let code = PolynomialCode::with_thetas(cfg, &integer_thetas(5))?;
    println!("\n=== (s={s}, m={m}): transmit l/m = {} scalars, wait for {} workers", 2 / m, 5 - s);

    // l = 2 toy gradients (one per data subset).
    let grads: Vec<Vec<f32>> = (0..5)
        .map(|t| vec![1.0 + t as f32, -1.0 - 0.5 * t as f32])
        .collect();
    let want = [
        grads.iter().map(|g| g[0]).sum::<f32>(),
        grads.iter().map(|g| g[1]).sum::<f32>(),
    ];

    let mut fs = Vec::new();
    for w in 0..5 {
        let enc = Encoder::new(&code, w)?;
        let views: Vec<&[f32]> = code
            .placement()
            .assigned(w)
            .iter()
            .map(|&t| grads[t].as_slice())
            .collect();
        fs.push(enc.encode(&views)?);
    }

    for straggler in 0..5 {
        let avail: Vec<usize> = (0..5).filter(|&w| w != straggler).collect();
        let dec = Decoder::new(&code, &avail)?;
        let views: Vec<&[f32]> =
            dec.used_workers().iter().map(|&w| fs[w].as_slice()).collect();
        let got = dec.decode(&views)?;
        assert!((got[0] - want[0]).abs() < 1e-4);
        assert!((got[1] - want[1]).abs() < 1e-4);
        if m == 2 {
            // Table II row: weights on f_i reconstructing each coordinate.
            let dw = code.decode_weights(&avail)?;
            let fmt = |u: usize| {
                dec.used_workers()
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| dw.weight(*i, u).abs() > 1e-12)
                    .map(|(i, w)| format!("{:+.3}·f{}", dw.weight(i, u), w + 1))
                    .collect::<Vec<_>>()
                    .join(" ")
            };
            println!(
                "  W{} straggles:  Σg(0) = {:<40}  Σg(1) = {}",
                straggler + 1,
                fmt(0),
                fmt(1)
            );
        } else {
            println!(
                "  W{} straggles: decoded Σg = [{:.1}, {:.1}] ✓",
                straggler + 1,
                got[0],
                got[1]
            );
        }
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("Fig. 2 tradeoff at n = k = 5, d = 3, θ = (-2,-1,0,1,2):");
    run_point(2, 1)?; // Fig. 2a
    run_point(1, 2)?; // Fig. 2b + Table II
    println!("\nBoth operating points of d = s + m verified on l = 2.");
    Ok(())
}
