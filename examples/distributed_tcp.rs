//! Multi-process coded training over real TCP sockets — the offline
//! analogue of the paper's mpi4py EC2 deployment.
//!
//! Spawns the `gradcode` binary as one leader + n worker OS *processes*
//! on loopback, exercising the full wire protocol (handshake, task
//! broadcast, arrival-ordered quorum, decode, checkpointing). Requires
//! `cargo build --release` first (the example locates the binary next to
//! itself).
//!
//!     cargo run --release --example distributed_tcp

use std::process::{Child, Command, Stdio};

fn gradcode_bin() -> std::path::PathBuf {
    // examples live in target/release/examples/; the binary one level up.
    let mut p = std::env::current_exe().expect("current_exe");
    p.pop(); // distributed_tcp
    p.pop(); // examples
    p.push("gradcode");
    p
}

fn main() -> anyhow::Result<()> {
    let bin = gradcode_bin();
    anyhow::ensure!(
        bin.exists(),
        "{} not found — run `cargo build --release` first",
        bin.display()
    );
    let n = 4;
    let addr = "127.0.0.1:17071";
    let ck = std::env::temp_dir().join("gradcode_tcp_demo.ck");
    let _ = std::fs::remove_file(&ck);

    println!("spawning leader + {n} worker processes on {addr}");
    let mut leader = Command::new(&bin)
        .args([
            "leader",
            "--listen",
            addr,
            "--n",
            &n.to_string(),
            "--s",
            "1",
            "--m",
            "2",
            "--iters",
            "60",
            "--rows",
            "256",
            "--dim",
            "512",
            "--lr",
            "0.02",
            "--checkpoint",
            ck.to_str().unwrap(),
        ])
        .stdout(Stdio::inherit())
        .spawn()?;
    // Give the listener a moment, then connect the workers.
    std::thread::sleep(std::time::Duration::from_millis(300));
    let workers: Vec<Child> = (0..n)
        .map(|id| {
            Command::new(&bin)
                .args(["worker", "--connect", addr, "--id", &id.to_string()])
                .stdout(Stdio::inherit())
                .spawn()
                .expect("spawn worker")
        })
        .collect();

    let status = leader.wait()?;
    anyhow::ensure!(status.success(), "leader exited with {status}");
    for (id, mut w) in workers.into_iter().enumerate() {
        let st = w.wait()?;
        anyhow::ensure!(st.success(), "worker {id} exited with {st}");
    }

    // The checkpoint written by the leader is a real artifact of the run.
    let ck_data = gradcode::checkpoint::Checkpoint::load(&ck)?;
    println!(
        "\ncheckpoint: iter {} | {} params | ‖β‖∞ = {:.4}",
        ck_data.iter,
        ck_data.beta.len(),
        ck_data.beta.iter().fold(0.0f32, |a, &x| a.max(x.abs()))
    );
    std::fs::remove_file(&ck).ok();
    println!("multi-process coded training over TCP: OK");
    Ok(())
}
