//! E9 — end-to-end driver: full coded distributed training through all
//! three layers.
//!
//! Trains logistic regression (the paper's §V workload, on the synthetic
//! Amazon-Employee-Access stand-in) with n = 10 workers under the paper's
//! delay model, comparing the three schemes of Fig. 3/4:
//! naive, best m=1 ([11]–[13]), and ours (m=2).
//!
//! When `make artifacts` has been run, the workers execute the
//! AOT-compiled JAX/Pallas `worker_step` artifact through PJRT (pass
//! `--backend rust` to force the pure-rust backend); otherwise it falls
//! back to the rust backend with a notice.
//!
//!     cargo run --release --example train_e2e -- [--iters 300] [--backend auto|rust|pjrt]

use gradcode::bench::Table;
use gradcode::cli::Command;
use gradcode::coordinator::{
    ExecutionMode, OptChoice, SchemeSpec, TrainConfig, Trainer,
};
use gradcode::data::{train_test_split, CategoricalConfig, DenseDataset, SyntheticCategorical};
use gradcode::metrics::RunLog;
use gradcode::simulator::DelayParams;

const N: usize = 10;
const ROWS_PER_SUBSET: usize = 64; // must match the artifact shape
const DIM: usize = 512; // must match the artifact shape

/// Whether PJRT artifacts are present (always false without the feature).
#[cfg(feature = "pjrt")]
fn pjrt_available() -> bool {
    use gradcode::runtime::Manifest;
    Manifest::load(&Manifest::default_dir()).map(|m| !m.is_empty()).unwrap_or(false)
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_available() -> bool {
    false
}

/// Build a PJRT-backed trainer; errors without the `pjrt` feature.
#[cfg(feature = "pjrt")]
fn pjrt_trainer(
    cfg: TrainConfig,
    scheme: SchemeSpec,
    train_ds: &DenseDataset,
    test_ds: &DenseDataset,
) -> anyhow::Result<Trainer> {
    use gradcode::runtime::{Manifest, PjrtBackend};
    use std::sync::Arc;
    let code = scheme.build(N)?;
    let backend = Arc::new(PjrtBackend::new(&Manifest::default_dir(), code.as_ref(), train_ds)?);
    Trainer::with_backend(cfg, code, backend, train_ds, Some(test_ds))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_trainer(
    _cfg: TrainConfig,
    _scheme: SchemeSpec,
    _train_ds: &DenseDataset,
    _test_ds: &DenseDataset,
) -> anyhow::Result<Trainer> {
    anyhow::bail!("--backend pjrt requires building with --features pjrt")
}

fn main() -> anyhow::Result<()> {
    let args = Command::new("train_e2e", "end-to-end coded training driver")
        .flag("iters", "300", "training iterations per scheme")
        .flag("seed", "2018", "experiment seed")
        .flag("backend", "auto", "auto | rust | pjrt")
        .flag("csv-dir", "", "if set, write per-run CSV files here")
        .parse_env();
    let iters = args.get_usize("iters");
    let seed = args.get_u64("seed");

    // Synthetic categorical data, padded to the artifact dimension.
    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 10, cardinality: (16, 48), ..Default::default() },
        seed,
    );
    let raw = gen.generate(N * ROWS_PER_SUBSET * 5 / 4, seed + 1);
    let (train_raw, test_ds) = train_test_split(&raw, 0.2, seed + 2);
    let train_ds = train_raw.pad_cols(DIM);
    println!(
        "dataset: {} train rows, {} test rows, l = {} (one-hot, padded), positive rate {:.2}",
        train_ds.rows, test_ds.rows, train_ds.cols, train_ds.positive_rate()
    );

    let want_pjrt = match args.get_str("backend") {
        "rust" => false,
        "pjrt" => true,
        _ => pjrt_available(),
    };

    let lr = 6.0 / train_ds.rows as f32;
    let schemes = [
        SchemeSpec::Uncoded,
        SchemeSpec::Poly { s: 2, m: 1 },
        SchemeSpec::Poly { s: 1, m: 2 },
    ];
    let mut logs: Vec<RunLog> = Vec::new();
    for scheme in schemes {
        let cfg = TrainConfig {
            n: N,
            scheme: scheme.clone(),
            iters,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: (iters / 20).max(1),
            delays: Some(DelayParams::ec2_fit()),
            mode: ExecutionMode::Virtual,
            seed,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let mut trainer = if want_pjrt {
            println!("[{}] backend: PJRT (AOT JAX/Pallas artifact)", scheme.label());
            pjrt_trainer(cfg, scheme, &train_ds, &test_ds)?
        } else {
            println!("[{}] backend: rust reference", scheme.label());
            Trainer::new(cfg, &train_ds, Some(&test_ds))?
        };
        let log = trainer.run()?;
        println!(
            "[{}] final loss {:.4}, test AUC {:.4}, total sim time {:.1}s, \
             mean iter {:.3}s, {:.1} MFloat transmitted",
            log.scheme,
            log.final_loss().unwrap_or(f64::NAN),
            log.final_auc().unwrap_or(f64::NAN),
            log.total_sim_time(),
            log.mean_iteration_sim_time(),
            log.total_floats_transmitted() as f64 / 1e6,
        );
        let dir = args.get_str("csv-dir");
        if !dir.is_empty() {
            std::fs::create_dir_all(dir)?;
            let path = format!("{dir}/e2e_{}.csv", log.scheme.replace(['(', ')', ',', '='], "_"));
            std::fs::write(&path, log.to_csv())?;
            println!("[{}] wrote {path}", log.scheme);
        }
        logs.push(log);
    }

    let mut table = Table::new(
        "end-to-end comparison (virtual clock, ec2-fit delay regime)",
        &["scheme", "mean iter (s)", "total time (s)", "final AUC", "floats/iter"],
    );
    for log in &logs {
        table.row(&[
            log.scheme.clone(),
            format!("{:.3}", log.mean_iteration_sim_time()),
            format!("{:.1}", log.total_sim_time()),
            format!("{:.4}", log.final_auc().unwrap_or(f64::NAN)),
            format!("{}", log.total_floats_transmitted() / log.records.len()),
        ]);
    }
    table.print();

    let naive_t = logs[0].mean_iteration_sim_time();
    let m1_t = logs[1].mean_iteration_sim_time();
    let ours_t = logs[2].mean_iteration_sim_time();
    println!(
        "ours vs naive: {:.0}% faster; ours vs m=1: {:.0}% faster",
        100.0 * (1.0 - ours_t / naive_t),
        100.0 * (1.0 - ours_t / m1_t)
    );
    println!("\nAUC-vs-time curves (paper Fig. 4 shape):");
    for log in &logs {
        let pts: Vec<String> = log
            .auc_curve()
            .iter()
            .map(|(t, a)| format!("({t:.0}s,{a:.3})"))
            .collect();
        println!("  {:<14} {}", log.scheme, pts.join(" "));
    }
    Ok(())
}
