//! Quickstart: build a coding scheme, encode at every worker, lose a
//! straggler, decode the exact sum gradient at the master.
//!
//!     cargo run --release --example quickstart

use gradcode::coding::{
    Decoder, Encoder, GradientCode, PolynomialCode, SchemeConfig,
};

fn main() -> anyhow::Result<()> {
    // n = 5 workers, tolerate s = 1 straggler, transmit l/m with m = 2.
    // Theorem 1 says each worker must then hold d = s + m = 3 subsets.
    let cfg = SchemeConfig::tight(5, 1, 2)?;
    let code = PolynomialCode::new(cfg)?;
    println!("scheme: n={} d={} s={} m={}", cfg.n, cfg.d, cfg.s, cfg.m);
    println!("placement (worker -> subsets):");
    for w in 0..cfg.n {
        println!("  W{w} -> {:?}", code.placement().assigned(w));
    }

    // Toy partial gradients g_0..g_4, each of dimension l = 6.
    let l = 6;
    let grads: Vec<Vec<f32>> = (0..cfg.n)
        .map(|t| (0..l).map(|k| (t * l + k) as f32 * 0.1).collect())
        .collect();
    let want: Vec<f32> =
        (0..l).map(|k| grads.iter().map(|g| g[k]).sum()).collect();

    // Each worker transmits an l/m = 3-dimensional coded vector.
    let mut transmitted = Vec::new();
    for w in 0..cfg.n {
        let enc = Encoder::new(&code, w)?;
        let views: Vec<&[f32]> = code
            .placement()
            .assigned(w)
            .iter()
            .map(|&t| grads[t].as_slice())
            .collect();
        let f = enc.encode(&views)?;
        println!("W{w} transmits {f:?}  ({} floats instead of {l})", f.len());
        transmitted.push(f);
    }

    // Worker 2 straggles; decode from the other four.
    let available: Vec<usize> = (0..cfg.n).filter(|&w| w != 2).collect();
    let dec = Decoder::new(&code, &available)?;
    let fs: Vec<&[f32]> = dec
        .used_workers()
        .iter()
        .map(|&w| transmitted[w].as_slice())
        .collect();
    let got = dec.decode(&fs)?;

    println!("\nmaster decodes (W2 straggled): {got:?}");
    println!("true sum gradient:             {want:?}");
    let err = got
        .iter()
        .zip(&want)
        .fold(0.0f32, |a, (&x, &y)| a.max((x - y).abs()));
    println!("max abs error: {err:.2e}");
    assert!(err < 1e-4);
    println!("OK — sum gradient recovered exactly from n-s workers.");
    Ok(())
}
