//! Straggler-injection demo on the *real-time* execution path: workers
//! actually sleep their sampled delays (scaled down), the master races
//! the first n-s arrivals off the wire, and late results are discarded.
//!
//! Shows (a) that training proceeds identically despite rotating
//! stragglers and (b) the wall-clock advantage of not waiting for the
//! slowest worker.
//!
//!     cargo run --release --example straggler_demo

use std::time::Instant;

use gradcode::coordinator::{
    train, ExecutionMode, OptChoice, SchemeSpec, TrainConfig,
};
use gradcode::data::{train_test_split, CategoricalConfig, SyntheticCategorical};
use gradcode::simulator::DelayParams;

fn main() -> anyhow::Result<()> {
    let gen = SyntheticCategorical::new(
        CategoricalConfig { columns: 8, ..Default::default() },
        99,
    );
    let raw = gen.generate(1000, 100);
    let (train_ds, test_ds) = train_test_split(&raw, 0.2, 101);
    let lr = 6.0 / train_ds.rows as f32;
    // 1 unit of virtual delay = 2 ms of real sleep: a full run stays
    // under a minute while the straggler race is physically real.
    let scale = 2e-3;
    let iters = 40;

    let mut rows = Vec::new();
    for (label, scheme, mode) in [
        ("naive (waits for all)", SchemeSpec::Uncoded, ExecutionMode::RealTime { scale }),
        ("coded s=2,m=1", SchemeSpec::Poly { s: 2, m: 1 }, ExecutionMode::RealTime { scale }),
        ("coded s=1,m=2", SchemeSpec::Poly { s: 1, m: 2 }, ExecutionMode::RealTime { scale }),
    ] {
        let cfg = TrainConfig {
            n: 8,
            scheme,
            iters,
            opt: OptChoice::Nag { lr, momentum: 0.9 },
            eval_every: iters,
            delays: Some(DelayParams::ec2_fit()),
            mode,
            seed: 5,
            minibatch: None,
            quorum: None,
            fleet: None,
            chaos: None,
        };
        let t0 = Instant::now();
        let (log, _) = train(cfg, &train_ds, Some(&test_ds))?;
        let wall = t0.elapsed().as_secs_f64();
        // how many distinct straggler patterns were seen?
        let distinct: std::collections::HashSet<_> =
            log.records.iter().map(|r| r.responders.clone()).collect();
        println!(
            "{label:<22} wall {wall:>6.2}s  AUC {:.4}  responder sets seen: {}",
            log.final_auc().unwrap_or(f64::NAN),
            distinct.len()
        );
        rows.push((label, wall));
    }
    let naive = rows[0].1;
    for (label, wall) in &rows[1..] {
        println!("{label}: {:.0}% faster than naive (real wall-clock)", 100.0 * (1.0 - wall / naive));
    }
    Ok(())
}
