//! E8 — the §VI runtime model as a planning tool.
//!
//! Prints the optimal (d, s, m) for a delay regime, the closed-form
//! extremes (Propositions 1 and 2), and a Monte-Carlo validation of the
//! quadrature expectation.
//!
//!     cargo run --release --example runtime_model -- --n 10 --lambda1 0.6 --t1 1.5 --lambda2 0.1 --t2 6

use gradcode::cli::Command;
use gradcode::simulator::optimize::{naive_choice, optimal_triple_m1};
use gradcode::simulator::order_stats::expected_total_runtime;
use gradcode::simulator::{
    optimal_alpha, optimal_triple, prop1_optimal_d, DelayParams, VirtualCluster,
};

fn main() {
    let a = Command::new("runtime_model", "§VI planning tool")
        .flag("n", "10", "workers")
        .flag("lambda1", "0.6", "computation straggling rate")
        .flag("t1", "1.5", "min per-subset computation time")
        .flag("lambda2", "0.1", "communication straggling rate")
        .flag("t2", "6", "min full-vector communication time")
        .parse_env();
    let n = a.get_usize("n");
    let p = DelayParams {
        lambda1: a.get_f64("lambda1"),
        t1: a.get_f64("t1"),
        lambda2: a.get_f64("lambda2"),
        t2: a.get_f64("t2"),
    };
    println!("delay model: {p:?}, n = {n}\n");

    let best = optimal_triple(&p, n);
    let m1 = optimal_triple_m1(&p, n);
    let naive = naive_choice(&p, n);
    println!("optimal design      (d={}, s={}, m={})  E[T_tot] = {:.4}", best.d, best.s, best.m, best.expected_runtime);
    println!("best m=1 [11]-[13]  (d={}, s={}, m=1)  E[T_tot] = {:.4}", m1.d, m1.s, m1.expected_runtime);
    println!("naive uncoded       (d=1, s=0, m=1)  E[T_tot] = {:.4}", naive.expected_runtime);
    println!(
        "improvement: {:.0}% vs m=1, {:.0}% vs naive\n",
        100.0 * (1.0 - best.expected_runtime / m1.expected_runtime),
        100.0 * (1.0 - best.expected_runtime / naive.expected_runtime)
    );

    // Monte-Carlo validation of the quadrature.
    let mut vc = VirtualCluster::new(&p, n, best.d, best.s, best.m, 42);
    let mc = vc.mean_iteration_time(50_000);
    println!(
        "Monte-Carlo check at the optimum: simulated {:.4} vs quadrature {:.4} ({:+.2}%)\n",
        mc,
        best.expected_runtime,
        100.0 * (mc / best.expected_runtime - 1.0)
    );

    // Proposition 1 (computation-dominant extreme).
    println!(
        "Prop 1 (ignore communication): optimal d = {} (threshold λ₁t₁ = {:.3})",
        prop1_optimal_d(&p, n),
        p.lambda1 * p.t1
    );
    // Proposition 2 (communication-dominant extreme).
    let alpha = optimal_alpha(p.lambda2, p.t2);
    println!(
        "Prop 2 (ignore computation, large n): optimal m/n = {alpha:.3} → m ≈ {:.1} at n = {n}",
        alpha * n as f64
    );

    // Sensitivity: one row per d showing the best m for that load.
    println!("\nE[T_tot] by (d, best m):");
    for d in 1..=n {
        let (mut bm, mut bv) = (1, f64::INFINITY);
        for m in 1..=d {
            let v = expected_total_runtime(&p, n, d, d - m, m);
            if v < bv {
                bv = v;
                bm = m;
            }
        }
        let marker = if d == best.d { "  <-- optimal" } else { "" };
        println!("  d={d:>2}: best m={bm}  E[T]={bv:.4}{marker}");
    }
}
