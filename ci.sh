#!/usr/bin/env bash
# Local CI gate for gradcode (documented in README.md).
#
#   ./ci.sh            # full gate
#   ./ci.sh --quick    # skip the bench smoke + doc build
#
# Steps:
#   1. cargo build --release --benches  (benches are autobenches=false /
#                                        test=false, so nothing else
#                                        compiles them)
#   2. cargo test -q          (unit + integration + doc tests)
#   3. chaos stage            (property/fuzz suites pinned to a fixed
#                              TESTKIT_SEED, under a hard wall-clock
#                              limit — a deadlocked gather must fail the
#                              gate, not hang it — plus a 30-iteration
#                              --chaos smoke train through the CLI)
#   4. obs stage              (30-iteration traced train smoke writing a
#                              telemetry JSONL, trace-report over it, and
#                              obs_overhead --smoke refreshing the
#                              machine-readable BENCH_obs.json — per-phase
#                              means + the traced-vs-untraced overhead
#                              delta)
#   5. hetero_speedup --smoke (tiny profile sweep; refreshes the
#                              machine-readable BENCH_hetero.json at the
#                              repo root so perf is tracked PR-over-PR)
#   6. cargo doc --no-deps    (lib.rs denies broken intra-doc links)
#   7. cargo fmt --check      (advisory: warns on drift, does not fail —
#                              rustfmt availability varies across the
#                              offline build images)
set -euo pipefail
cd "$(dirname "$0")"

quick=0
[ "${1:-}" = "--quick" ] && quick=1

echo "==> cargo build --release (lib, bin, benches)"
cargo build --release
cargo build --release --benches

echo "==> cargo test -q"
cargo test -q

echo "==> chaos stage (fixed seed, hard wall-clock limit)"
# The chaos/fuzz suites assert "never hangs"; enforce that from the
# outside too so a deadlock fails the gate instead of stalling it.
# The seed is pinned for reproducibility — override by exporting
# TESTKIT_SEED before running ci.sh.
chaos_timeout=600
run_limited() {
    if command -v timeout >/dev/null 2>&1; then
        timeout --signal=KILL "$chaos_timeout" "$@"
    else
        "$@"
    fi
}
TESTKIT_SEED="${TESTKIT_SEED:-0x5eedc0de}" run_limited \
    cargo test -q --test chaos_recovery --test wire_fuzz

echo "==> chaos smoke train (30 iters through the CLI)"
run_limited ./target/release/gradcode train \
    --n 6 --s 2 --m 1 --iters 30 --rows 240 \
    --chaos crash=0.02,drop=0.1,corrupt=0.05,dup=0.02,seed=0xc4a05
run_limited ./target/release/gradcode chaos-report \
    --n 6 --s 2 --iters 30 --rows 240 --chaos drop=0.2,seed=3

echo "==> obs smoke: traced train + trace-report"
obs_trace="target/ci_trace.jsonl"
run_limited ./target/release/gradcode train \
    --n 6 --s 1 --m 2 --iters 30 --rows 240 --trace "$obs_trace"
[ -s "$obs_trace" ] || { echo "FAIL: traced train wrote no telemetry"; exit 1; }
run_limited ./target/release/gradcode trace-report "$obs_trace" --csv \
    --chrome target/ci_trace.chrome.json

if [ "$quick" -eq 0 ]; then
    echo "==> bench smoke: obs_overhead (writes BENCH_obs.json)"
    cargo bench --bench obs_overhead -- --smoke

    echo "==> bench smoke: hetero_speedup (writes BENCH_hetero.json)"
    cargo bench --bench hetero_speedup -- --smoke

    echo "==> cargo doc --no-deps"
    cargo doc --no-deps
fi

echo "==> cargo fmt --check (advisory)"
if command -v rustfmt >/dev/null 2>&1; then
    cargo fmt --check || echo "WARNING: formatting drift (non-fatal; run 'cargo fmt')"
else
    echo "rustfmt not installed; skipping"
fi

echo "CI gate passed."
