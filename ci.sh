#!/usr/bin/env bash
# Local CI gate for gradcode (documented in README.md).
#
#   ./ci.sh                     # full gate
#   ./ci.sh --quick             # skip bench smokes, ci-gate + doc build
#   ./ci.sh --update-baselines  # full gate, then promote target/bench/
#                               # BENCH_*.json to the repo-root baselines
#
# Steps:
#   1. cargo build --release --benches  (benches are autobenches=false /
#                                        test=false, so nothing else
#                                        compiles them)
#   2. cargo test -q          (unit + integration + doc tests)
#   3. gradcode lint --deny   (in-repo static analysis: determinism,
#                              panic-hygiene, lock-discipline and
#                              wire-versioning rules; writes the machine
#                              report to target/lint_report.json, then
#                              fails on any finding not grandfathered in
#                              lint.baseline — the baseline ships empty)
#   4. chaos stage            (property/fuzz suites pinned to a fixed
#                              TESTKIT_SEED, under a hard wall-clock
#                              limit — a deadlocked gather must fail the
#                              gate, not hang it — plus a 30-iteration
#                              --chaos smoke train through the CLI)
#   5. obs stage              (30-iteration traced train smoke writing a
#                              fresh telemetry JSONL, trace-report over it
#                              in CSV/Chrome/Prometheus forms, then a
#                              second train serving --metrics-addr that a
#                              /dev/tcp scrape must see metric families on)
#   6. threads determinism    (the same train at --threads 1 and
#                              --threads 4 must print identical results —
#                              the pool's bitwise-determinism contract)
#   7. bench smokes           (obs_overhead / hetero_speedup / hotpath
#                              --smoke, each writing its machine-readable
#                              BENCH_*.json under target/bench/ — never
#                              over the committed repo-root baselines)
#   8. gradcode ci-gate       (compare target/bench/BENCH_*.json against
#                              the committed baselines; >15% regression
#                              of a headline metric fails the gate;
#                              --update-baselines promotes instead)
#   9. cargo doc --no-deps    (lib.rs denies broken intra-doc links)
#  10. cargo fmt --check      (advisory: warns on drift, does not fail —
#                              rustfmt availability varies across the
#                              offline build images)
set -euo pipefail
cd "$(dirname "$0")"

quick=0
update_baselines=0
for arg in "$@"; do
    case "$arg" in
        --quick) quick=1 ;;
        --update-baselines) update_baselines=1 ;;
        *) echo "unknown flag: $arg (known: --quick, --update-baselines)"; exit 2 ;;
    esac
done

# Advisory findings collected along the way; printed in the final
# summary so they don't scroll away behind the bench output.
warnings=()

echo "==> cargo build --release (lib, bin, benches)"
cargo build --release
cargo build --release --benches

echo "==> cargo test -q"
cargo test -q

echo "==> gradcode lint (static analysis, --deny)"
# Write the machine-readable report first so the artifact survives a
# failing gate, then enforce: any finding outside lint.baseline fails.
mkdir -p target
./target/release/gradcode lint --json > target/lint_report.json
./target/release/gradcode lint --deny

echo "==> chaos stage (fixed seed, hard wall-clock limit)"
# The chaos/fuzz suites assert "never hangs"; enforce that from the
# outside too so a deadlock fails the gate instead of stalling it.
# The seed is pinned for reproducibility — override by exporting
# TESTKIT_SEED before running ci.sh.
chaos_timeout=600
run_limited() {
    if command -v timeout >/dev/null 2>&1; then
        timeout --signal=KILL "$chaos_timeout" "$@"
    else
        "$@"
    fi
}
TESTKIT_SEED="${TESTKIT_SEED:-0x5eedc0de}" run_limited \
    cargo test -q --test chaos_recovery --test wire_fuzz

echo "==> chaos smoke train (30 iters through the CLI)"
run_limited ./target/release/gradcode train \
    --n 6 --s 2 --m 1 --iters 30 --rows 240 \
    --chaos crash=0.02,drop=0.1,corrupt=0.05,dup=0.02,seed=0xc4a05
run_limited ./target/release/gradcode chaos-report \
    --n 6 --s 2 --iters 30 --rows 240 --chaos drop=0.2,seed=3

echo "==> obs smoke: traced train + trace-report"
obs_trace="target/ci_trace.jsonl"
# A stale trace from an earlier run would mask a train that wrote
# nothing; start clean.
rm -f "$obs_trace" target/ci_trace.chrome.json
run_limited ./target/release/gradcode train \
    --n 6 --s 1 --m 2 --iters 30 --rows 240 --trace "$obs_trace"
[ -s "$obs_trace" ] || { echo "FAIL: traced train wrote no telemetry"; exit 1; }
run_limited ./target/release/gradcode trace-report "$obs_trace" --csv \
    --chrome target/ci_trace.chrome.json
# The same replay must render as Prometheus text through the shared
# exposition renderer.
run_limited ./target/release/gradcode trace-report "$obs_trace" --prom \
    | grep -q '^# TYPE gradcode_' \
    || { echo "FAIL: trace-report --prom produced no metric families"; exit 1; }

echo "==> obs smoke: live Prometheus scrape during train (--metrics-addr)"
obs_metrics_log="target/ci_metrics_train.log"
rm -f "$obs_metrics_log"
# Port 0 picks a free port; the trainer announces the bound address on
# stdout and --metrics-linger keeps the endpoint up until one scrape
# lands, so a short run cannot finish before the scraper gets there.
run_limited ./target/release/gradcode train \
    --n 6 --s 1 --m 2 --iters 30 --rows 240 \
    --metrics-addr 127.0.0.1:0 --metrics-linger 60 >"$obs_metrics_log" 2>&1 &
train_pid=$!
metrics_addr=""
for _ in $(seq 1 200); do
    metrics_addr="$(sed -n 's|^metrics: serving Prometheus text on http://\([0-9.:]*\)/metrics$|\1|p' "$obs_metrics_log" | head -n1)"
    [ -n "$metrics_addr" ] && break
    sleep 0.1
done
if [ -z "$metrics_addr" ]; then
    cat "$obs_metrics_log"
    echo "FAIL: train never announced a metrics address"
    kill "$train_pid" 2>/dev/null || true
    exit 1
fi
scrape="$( (exec 3<>"/dev/tcp/${metrics_addr%:*}/${metrics_addr##*:}"; \
    printf 'GET /metrics HTTP/1.0\r\n\r\n' >&3; cat <&3) 2>/dev/null || true)"
wait "$train_pid" || { cat "$obs_metrics_log"; echo "FAIL: train with --metrics-addr failed"; exit 1; }
printf '%s' "$scrape" | grep -q '^# TYPE gradcode_' \
    || { echo "FAIL: live scrape returned no gradcode metric families"; printf '%s\n' "$scrape" | head -20; exit 1; }
echo "live scrape: $(printf '%s' "$scrape" | grep -c '^# TYPE') metric families"

echo "==> threads determinism smoke (--threads 1 vs --threads 4)"
# The summary line (losses, wire bytes, sim times) is a pure function of
# the seed; the pool contract says the thread count must not change it.
threads_args=(--n 6 --s 1 --m 2 --iters 25 --rows 240 --seed 11)
out1="$(run_limited ./target/release/gradcode train "${threads_args[@]}" --threads 1 | grep '^scheme=')"
out4="$(run_limited ./target/release/gradcode train "${threads_args[@]}" --threads 4 | grep '^scheme=')"
if [ "$out1" != "$out4" ]; then
    echo "FAIL: results differ between --threads 1 and --threads 4"
    echo "  1: $out1"
    echo "  4: $out4"
    exit 1
fi
echo "bitwise identical: $out1"

if [ "$quick" -eq 0 ]; then
    # Fresh bench artifacts land in target/bench/, NOT the repo root:
    # the repo-root BENCH_*.json are the committed baselines the gate
    # compares against, and a smoke run must never overwrite its own
    # yardstick. Promotion is explicit via --update-baselines.
    mkdir -p target/bench

    echo "==> bench smoke: obs_overhead (writes target/bench/BENCH_obs.json)"
    cargo bench --bench obs_overhead -- --smoke --json target/bench/BENCH_obs.json

    echo "==> bench smoke: hetero_speedup (writes target/bench/BENCH_hetero.json)"
    cargo bench --bench hetero_speedup -- --smoke --json target/bench/BENCH_hetero.json

    echo "==> bench smoke: hotpath thread sweep (writes target/bench/BENCH_hotpath.json)"
    cargo bench --bench hotpath -- --smoke --json target/bench/BENCH_hotpath.json

    if [ "$update_baselines" -eq 1 ]; then
        echo "==> promoting target/bench/BENCH_*.json to repo-root baselines"
        cp target/bench/BENCH_*.json .
        git status --short -- 'BENCH_*.json' || true
    else
        echo "==> gradcode ci-gate (fresh vs committed baselines)"
        if ls BENCH_*.json >/dev/null 2>&1; then
            ./target/release/gradcode ci-gate --current target/bench --baseline .
        else
            warnings+=("no committed BENCH_*.json baselines; ci-gate skipped — run './ci.sh --update-baselines' once and commit the result")
            echo "(no committed baselines yet; skipping the gate)"
        fi
    fi

    echo "==> cargo doc --no-deps"
    cargo doc --no-deps
fi

echo "==> cargo fmt --check (advisory)"
if command -v rustfmt >/dev/null 2>&1; then
    cargo fmt --check || warnings+=("formatting drift (run 'cargo fmt')")
else
    warnings+=("rustfmt not installed; format check skipped")
fi

echo
echo "=== summary ==="
if [ "${#warnings[@]}" -gt 0 ]; then
    echo "advisory warnings (gate still passed):"
    for w in "${warnings[@]}"; do
        echo "  - $w"
    done
else
    echo "no advisory warnings."
fi
echo "CI gate passed."
