#!/usr/bin/env bash
# Local CI gate for gradcode (documented in README.md).
#
#   ./ci.sh            # full gate
#   ./ci.sh --quick    # skip the bench smoke + doc build
#
# Steps:
#   1. cargo build --release --benches  (benches are autobenches=false /
#                                        test=false, so nothing else
#                                        compiles them)
#   2. cargo test -q          (unit + integration + doc tests)
#   3. hetero_speedup --smoke (tiny profile sweep; refreshes the
#                              machine-readable BENCH_hetero.json at the
#                              repo root so perf is tracked PR-over-PR)
#   4. cargo doc --no-deps    (lib.rs denies broken intra-doc links)
#   5. cargo fmt --check      (advisory: warns on drift, does not fail —
#                              rustfmt availability varies across the
#                              offline build images)
set -euo pipefail
cd "$(dirname "$0")"

quick=0
[ "${1:-}" = "--quick" ] && quick=1

echo "==> cargo build --release (lib, bin, benches)"
cargo build --release
cargo build --release --benches

echo "==> cargo test -q"
cargo test -q

if [ "$quick" -eq 0 ]; then
    echo "==> bench smoke: hetero_speedup (writes BENCH_hetero.json)"
    cargo bench --bench hetero_speedup -- --smoke

    echo "==> cargo doc --no-deps"
    cargo doc --no-deps
fi

echo "==> cargo fmt --check (advisory)"
if command -v rustfmt >/dev/null 2>&1; then
    cargo fmt --check || echo "WARNING: formatting drift (non-fatal; run 'cargo fmt')"
else
    echo "rustfmt not installed; skipping"
fi

echo "CI gate passed."
